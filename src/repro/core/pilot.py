"""The pilot abstraction (paper §II-A) adapted to the JAX/TPU continuum.

A *pilot* is a placeholder resource container acquired once and multiplexed
by application tasks; resource management is decoupled from workload
management. On the original infrastructure a pilot is a VM / HPC partition /
RasPi. Here a pilot is a **named slice of compute**:

* ``tier='device'`` — sensor-class SoC slots right next to the data —
  generation only;
* ``tier='edge'``   — host CPU thread slots (the paper's RasPi-class Dask
  task: 1 core / ~4 GB) — data generation, light pre-processing;
* ``tier='fog'``    — metro gateway boxes between edge site and
  datacenter — aggregation along the path;
* ``tier='cloud'``  — a sub-mesh slice of the JAX device mesh (on CPU-only
  containers this is a slice of host devices; on TPU the same code slices the
  pod) — heavy processing, training, serving;
* ``tier='hpc'``    — like cloud, different accounting label.

The :class:`PilotManager` plays the paper's pilot framework: it owns the
global device inventory, performs admission (no oversubscription of devices
across pilots), builds per-pilot :class:`jax.sharding.Mesh` objects, and can
``resize``/``release`` pilots at runtime (the paper's dynamism requirement —
see also core/elastic.py).

Plugin architecture (paper §II-B): resource *descriptions* say what backs a
pilot; new backends register via :func:`register_backend` the way
Pilot-Streaming registers OpenStack/AWS/SSH plugins.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.sim.clock import Clock, as_clock

# the default continuum's tier names (device → edge → fog → cloud, plus
# the hpc accounting tier). Custom topologies may use any non-empty tier
# name — tiers are continuum-profile keys, not a closed enum.
TIERS = ("device", "edge", "fog", "cloud", "hpc")


@dataclass(frozen=True)
class ComputeResource:
    """Paper's pilot_compute_description analog: what to allocate where."""
    tier: str                         # device | edge | fog | cloud | hpc | …
    n_devices: int = 0                # mesh devices (cloud/hpc pilots)
    n_workers: int = 1                # executor threads (edge pilots)
    mesh_axes: tuple = ("data",)      # axis names for the pilot's sub-mesh
    mesh_shape: Optional[tuple] = None
    memory_gb: float = 4.0            # admission accounting only
    backend: str = "local"            # plugin key (local | ssh | openstack…)
    label: str = ""

    def __post_init__(self):
        if not self.tier or not isinstance(self.tier, str):
            raise ValueError(f"tier must be a non-empty string (e.g. one "
                             f"of {TIERS}), got {self.tier!r}")


class PilotError(RuntimeError):
    pass


_pilot_ids = itertools.count()


@dataclass
class Pilot:
    """An acquired resource container. Tasks bind to a pilot at submit time
    (late binding = the placement decision)."""
    pilot_id: str
    resource: ComputeResource
    devices: tuple = ()
    mesh: Optional[jax.sharding.Mesh] = None
    state: str = "active"             # active | draining | released | failed
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    @property
    def tier(self) -> str:
        return self.resource.tier

    @property
    def capacity(self) -> int:
        """Concurrent task slots: workers (edge) or 1 SPMD slot (mesh)."""
        if self.mesh is not None:
            return 1
        return self.resource.n_workers

    def require_active(self) -> None:
        if self.state != "active":
            raise PilotError(f"pilot {self.pilot_id} is {self.state}")

    def fail(self) -> None:
        with self._lock:
            self.state = "failed"

    def __hash__(self):
        return hash(self.pilot_id)


# -- backend plugins ----------------------------------------------------------

_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str):
    def deco(fn):
        _BACKENDS[name] = fn
        return fn
    return deco


@register_backend("local")
def _local_backend(resource: ComputeResource,
                   devices: Sequence) -> tuple:
    """Default backend: slice local jax devices for mesh pilots."""
    return tuple(devices)


class PilotManager:
    """Owns the device inventory; admits, resizes, releases pilots.

    The manager never runs workload code — that is the decoupling the paper's
    abstraction is built on. The FaaS layer (core/faas.py) binds functions to
    pilots *after* acquisition.
    """

    def __init__(self, devices: Optional[Sequence] = None,
                 clock: Optional[Clock] = None,
                 heartbeat_timeout_s: float = 30.0):
        self._lock = threading.Lock()
        self._clock = as_clock(clock)
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self._all_devices = tuple(devices if devices is not None
                                  else jax.devices())
        self._free = list(self._all_devices)
        self._pilots: Dict[str, Pilot] = {}
        self._heartbeats: Dict[str, float] = {}

    # -- inventory ---------------------------------------------------------

    @property
    def free_devices(self) -> int:
        with self._lock:
            return len(self._free)

    def pilots(self, tier: Optional[str] = None) -> List[Pilot]:
        with self._lock:
            ps = [p for p in self._pilots.values() if p.state == "active"]
        if tier:
            ps = [p for p in ps if p.tier == tier]
        return ps

    # -- lifecycle -----------------------------------------------------------

    def submit_pilot(self, resource: ComputeResource) -> Pilot:
        """Paper's step 1: allocate a placeholder resource container."""
        backend = _BACKENDS.get(resource.backend)
        if backend is None:
            raise PilotError(f"unknown backend {resource.backend!r}; "
                             f"registered: {sorted(_BACKENDS)}")
        with self._lock:
            devices: tuple = ()
            mesh = None
            if resource.n_devices > 0:
                if len(self._free) < resource.n_devices:
                    raise PilotError(
                        f"admission failed: want {resource.n_devices} "
                        f"devices, {len(self._free)} free")
                devices = backend(resource, self._free[:resource.n_devices])
                self._free = self._free[resource.n_devices:]
                mesh = self._make_mesh(devices, resource)
            pid = f"pilot-{resource.tier}-{next(_pilot_ids)}"
            pilot = Pilot(pilot_id=pid, resource=resource,
                          devices=devices, mesh=mesh)
            self._pilots[pid] = pilot
            self._heartbeats[pid] = self._clock.now()
            return pilot

    # -- liveness ------------------------------------------------------------

    def heartbeat(self, pilot: Pilot) -> None:
        """Pilot liveness beat (the paper's failure detection across the
        continuum); stamped on the injected clock so emulated scenarios can
        schedule silent node loss."""
        with self._lock:
            self._heartbeats[pilot.pilot_id] = self._clock.now()

    def last_heartbeat(self, pilot: Pilot) -> Optional[float]:
        with self._lock:
            return self._heartbeats.get(pilot.pilot_id)

    def check_liveness(self,
                       timeout_s: Optional[float] = None) -> List[Pilot]:
        """Mark active pilots whose last beat is older than the timeout as
        failed (their devices are gone — a node loss, not a release).
        Returns the newly failed pilots."""
        timeout = (self.heartbeat_timeout_s
                   if timeout_s is None else timeout_s)
        now = self._clock.now()
        lost: List[Pilot] = []
        with self._lock:
            for pid, p in self._pilots.items():
                if p.state != "active":
                    continue
                beat = self._heartbeats.get(pid)
                if beat is not None and now - beat > timeout:
                    p.fail()
                    lost.append(p)
        return lost

    @staticmethod
    def _make_mesh(devices: tuple, resource: ComputeResource):
        shape = resource.mesh_shape or (len(devices),)
        if int(np.prod(shape)) != len(devices):
            raise PilotError(f"mesh_shape {shape} != {len(devices)} devices")
        arr = np.array(devices, dtype=object).reshape(shape)
        return jax.sharding.Mesh(arr, resource.mesh_axes)

    def resize(self, pilot: Pilot, n_devices: Optional[int] = None,
               n_workers: Optional[int] = None) -> Pilot:
        """Elastic scale-up/down at runtime (paper §II-D). Returns a *new*
        Pilot object with the same id; in-flight SPMD tasks must be re-bound
        by the caller (core/elastic.py orchestrates re-mesh + reshard)."""
        pilot.require_active()
        res = pilot.resource
        with self._lock:
            if n_devices is not None and res.n_devices != n_devices:
                delta = n_devices - res.n_devices
                if delta > 0:
                    if len(self._free) < delta:
                        raise PilotError(
                            f"resize failed: want {delta} more devices, "
                            f"{len(self._free)} free")
                    new_devices = pilot.devices + tuple(self._free[:delta])
                    self._free = self._free[delta:]
                else:
                    new_devices = pilot.devices[:n_devices]
                    self._free.extend(pilot.devices[n_devices:])
                res = dataclasses.replace(res, n_devices=n_devices,
                                          mesh_shape=None)
                pilot.devices = new_devices
                pilot.mesh = (self._make_mesh(new_devices, res)
                              if new_devices else None)
            if n_workers is not None:
                res = dataclasses.replace(res, n_workers=n_workers)
            pilot.resource = res
            return pilot

    def release(self, pilot: Pilot) -> None:
        with self._lock:
            if pilot.state == "released":
                return
            pilot.state = "released"
            self._free.extend(pilot.devices)
            pilot.devices = ()
            pilot.mesh = None

    def mark_failed(self, pilot: Pilot) -> None:
        """Failure detector hook: devices of a failed pilot are *not*
        returned to the free pool (they are gone), matching a node loss."""
        with self._lock:
            pilot.fail()

    def release_all(self) -> None:
        for p in list(self._pilots.values()):
            if p.state == "active":
                self.release(p)
