"""Elasticity + fault tolerance orchestration (paper §II-D, §V challenge ii).

"If supported by the resource, the allocated resources can be adapted, i.e.,
expanded and scaled-down, dynamically at runtime, e.g., if a bottleneck
arises due to increased data rates or in response to an application event."

Two mechanisms:

1. :class:`AutoScaler` — watches a pipeline's broker lag + per-hop latencies
   (the paper's bottleneck identification) and calls ``PilotManager.resize``
   when the consuming side falls behind (the paper's four-partition scenario
   where "the processing system becomes the bottleneck").

2. :func:`remesh_restart` — node-loss recovery for mesh pilots: given a
   checkpoint and a *smaller* surviving device set, rebuild the mesh, reshard
   the checkpointed train state onto it, and return a rebound step function.
   This is the multi-pod story: lose a pod → restart on the surviving pod
   from the last checkpoint (ckpt/ handles reshard-on-restore).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core.monitoring import MetricsRegistry
from repro.core.pilot import Pilot, PilotManager
from repro.sim.clock import Clock, as_clock


@dataclass
class ScalePolicy:
    max_workers: int = 16
    min_workers: int = 1
    lag_high: int = 64            # scale up when broker lag exceeds this
    lag_low: int = 4              # scale down when lag stays below this
    cooldown_s: float = 1.0


class AutoScaler:
    """Lag-driven scaling of a consuming pilot's worker count."""

    def __init__(self, manager: PilotManager, pilot: Pilot,
                 lag_fn: Callable[[], int],
                 policy: Optional[ScalePolicy] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 interval_s: float = 0.2,
                 clock: Optional[Clock] = None):
        self.manager = manager
        self.pilot = pilot
        self.lag_fn = lag_fn
        self.policy = policy or ScalePolicy()
        self._clock = as_clock(clock)
        self.metrics = metrics or MetricsRegistry(clock=self._clock)
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # cooldowns measured on the injected clock; emulated scenarios can
        # step through hours of scaling decisions in zero wall time
        self._last_action = -float("inf")
        # every resize, timestamped on the injected clock — under
        # SimExecutor this trace is bit-identical across repeated runs
        self.history: List[Dict[str, float]] = []

    def step_once(self) -> Optional[int]:
        """One scaling decision; returns the new worker count if changed."""
        lag = self.lag_fn()
        now = self._clock.now()
        if now - self._last_action < self.policy.cooldown_s:
            return None
        workers = self.pilot.resource.n_workers
        new = None
        if lag > self.policy.lag_high and workers < self.policy.max_workers:
            new = min(workers * 2, self.policy.max_workers)
        elif lag < self.policy.lag_low and workers > self.policy.min_workers:
            new = max(workers // 2, self.policy.min_workers)
        if new is not None and new != workers:
            self.manager.resize(self.pilot, n_workers=new)
            self._last_action = now
            self.history.append({"t": now, "from_workers": workers,
                                 "to_workers": new, "lag": lag})
            self.metrics.event("autoscale", pilot=self.pilot.pilot_id,
                               from_workers=workers, to_workers=new,
                               lag=lag)
            return new
        return None

    def start(self) -> None:
        def loop():
            while not self._stop.wait(self.interval_s):
                try:
                    self.step_once()
                except Exception:   # noqa: BLE001 — scaler must not die
                    self.metrics.incr("autoscaler.errors")
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)


def remesh_restart(manager: PilotManager, failed_pilot: Pilot,
                   n_devices: int, *,
                   restore_fn: Callable,
                   metrics: Optional[MetricsRegistry] = None):
    """Recover from a mesh-pilot failure.

    1. mark the failed pilot (its devices are gone),
    2. admit a replacement pilot over ``n_devices`` surviving devices,
    3. call ``restore_fn(new_pilot)`` — typically
       ``ckpt.restore(..., mesh=new_pilot.mesh, pspecs=...)`` which reshards
       the last checkpoint onto the new (smaller) mesh,
    4. return (new_pilot, restored_state).
    """
    import dataclasses as _dc
    if metrics:
        metrics.event("pilot_failed", pilot=failed_pilot.pilot_id)
    manager.mark_failed(failed_pilot)
    res = _dc.replace(failed_pilot.resource, n_devices=n_devices,
                      mesh_shape=None)
    new_pilot = manager.submit_pilot(res)
    state = restore_fn(new_pilot)
    if metrics:
        metrics.event("pilot_recovered", pilot=new_pilot.pilot_id,
                      devices=n_devices)
    return new_pilot, state
