"""Partitioned message broker — the framework's Kafka analog (paper §II-B).

The paper routes all edge→cloud dataflow through a pilot-managed Kafka broker
with one partition per edge device. On the TPU-fabric adaptation the broker's
role is *flow decoupling + placement boundary + byte accounting*, not disk
durability (the checkpoint layer owns durability; see DESIGN.md §2). So:

* a :class:`Topic` is a set of partitions; each partition is an ordered
  in-memory queue with offsets (Kafka log semantics minus the disk),
* producers append to a partition (keyed or round-robin),
* consumer groups own partition→consumer assignments and track committed
  offsets, so replayed/failed consumers resume exactly like Kafka rebalance,
* every hop stamps the shared :class:`MetricsRegistry` (produced/broker_in/
  broker_out/consumed) with serialized byte sizes, which is what the paper's
  Fig 2 throughput/latency curves measure,
* an optional :class:`WanShaper` models the XSEDE↔LRZ geo hop (140–160 ms
  RTT, 60–100 Mbit/s iPerf band) with a token bucket + latency stamp —
  the paper's geographic-distribution experiment (Fig 3 right).

Serialization is real (numpy ``tobytes``): message size on the wire equals
the paper's 8 B/point accounting, and the WAN shaper charges the actual
serialized bytes.
"""
from __future__ import annotations

import io
import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.monitoring import MetricsRegistry
from repro.sim.clock import Clock, as_clock


# ---------------------------------------------------------------------------
# message + serialization
# ---------------------------------------------------------------------------

_msg_counter = itertools.count()


def _serialize(payload: Any) -> bytes:
    """numpy-first serialization; sizes match the paper's 8 B/float64 points."""
    if isinstance(payload, np.ndarray):
        buf = io.BytesIO()
        np.save(buf, payload, allow_pickle=False)
        return buf.getvalue()
    if isinstance(payload, bytes):
        return payload
    import pickle
    return pickle.dumps(payload)


def _deserialize(raw: bytes) -> Any:
    if raw[:6] == b"\x93NUMPY":
        return np.load(io.BytesIO(raw), allow_pickle=False)
    if raw[:1] == b"\x80":
        # our own _serialize always emits protocol>=2 pickles, which start
        # with the PROTO opcode — cheaper than a try/except pickle probe,
        # and raw bytes payloads (which can't start with \x80 unless they
        # really are pickles) round-trip untouched
        import pickle
        try:
            return pickle.loads(raw)
        except Exception:
            return raw
    return raw


@dataclass(slots=True)
class Message:
    msg_id: str
    key: Optional[str]
    raw: bytes
    offset: int = -1
    partition: int = -1

    @property
    def nbytes(self) -> int:
        return len(self.raw)

    def value(self) -> Any:
        return _deserialize(self.raw)


# ---------------------------------------------------------------------------
# WAN shaper (geo-distribution model)
# ---------------------------------------------------------------------------


@dataclass
class WanShaper:
    """Token-bucket bandwidth + fixed-latency model of the paper's
    intercontinental hop. ``bandwidth_bps`` is bits/s; ``rtt_s`` one-way
    latency is rtt/2 applied per message. Deterministic when ``sleep=False``
    (latency is *accounted* in the metrics clock instead of slept) so tests
    and benchmarks can run fast while still measuring the paper's numbers."""
    bandwidth_bps: float = 80e6          # 60–100 Mbit/s band midpoint
    rtt_s: float = 0.150                 # 140–160 ms band midpoint
    sleep: bool = False                  # real sleeps (live demo) or virtual
    _available_at: float = field(default=0.0, init=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, init=False)

    def delay_for(self, nbytes: int, now: float) -> float:
        """Seconds until the message clears the WAN, from ``now``."""
        tx = nbytes * 8.0 / self.bandwidth_bps
        with self._lock:
            start = max(now, self._available_at)
            self._available_at = start + tx       # serialize on the link
        return (start - now) + tx + self.rtt_s / 2.0


# ---------------------------------------------------------------------------
# broker
# ---------------------------------------------------------------------------


class _Partition:
    def __init__(self):
        self.log: List[Message] = []
        self.ready_at: List[float] = []      # WAN-shaped visibility time
        self.base = 0                        # absolute offset of log[0]
        self.truncated = 0                   # messages reclaimed so far
        self.cond = threading.Condition()

    def append(self, msg: Message, ready_at: float) -> int:
        with self.cond:
            msg.offset = self.base + len(self.log)
            # ready_at first: lock-free readers (poll_nowait) gate on
            # len(log), so by the time a message is observable its
            # visibility time is already in place
            self.ready_at.append(ready_at)
            self.log.append(msg)
            self.cond.notify_all()
            return msg.offset

    def append_unlocked(self, msg: Message, ready_at: float) -> int:
        """Single-owner append: no condition lock, no notify.  Only valid
        when one thread owns the whole broker (``Topic.single_owner``) —
        nobody blocks in ``poll`` then, so the notify is dead weight and
        the lock pure overhead."""
        msg.offset = self.base + len(self.log)
        self.ready_at.append(ready_at)
        self.log.append(msg)
        return msg.offset


class Topic:
    def __init__(self, name: str, n_partitions: int,
                 metrics: MetricsRegistry,
                 shaper: Optional[WanShaper] = None,
                 clock: Optional[Clock] = None,
                 truncate_batch: Optional[int] = None):
        self.name = name
        self.partitions = [_Partition() for _ in range(n_partitions)]
        self.metrics = metrics
        self.shaper = shaper
        # single-owner mode: set by the DES executor when exactly one
        # thread drives every producer/consumer of this topic.  Elides the
        # partition condition locks on the append / locked-poll / truncate
        # paths (the locked poll_nowait variant the truncation feature
        # added is the profiled hot spot this removes).
        self.single_owner = False
        self._clock = as_clock(clock)
        self._rr = itertools.count()
        # dict-keyed (insertion-ordered) so subscribe is idempotent and
        # unsubscribe is O(1); produce iterates an immutable snapshot tuple
        # rebuilt only on membership change — no per-message lock/copy
        self._subs: Dict[Any, None] = {}
        self._subs_cache: Tuple = ()
        self._subs_lock = threading.Lock()
        # log truncation (Kafka retention analog): entries strictly below
        # the minimum committed offset across registered consumer groups
        # are reclaimed in ``truncate_batch``-sized chunks.  None disables
        # truncation (the default: logs grow unboundedly, exactly the
        # pre-truncation behavior, and readers stay lock-free).
        self.truncate_batch = truncate_batch
        self._groups: Dict["ConsumerGroup", None] = {}
        self._groups_cache: Tuple = ()
        self._trunc_cbs: Dict[Any, None] = {}
        self._trunc_cbs_cache: Tuple = ()

    # -- append notifications ---------------------------------------------

    def subscribe(self, fn) -> None:
        """Register ``fn(partition, ready_at)`` to fire after every append.
        This is what makes event-driven consumers possible: instead of
        polling on a sleep cadence, a parked consumer is woken exactly when
        a message lands (or becomes WAN-visible). Callbacks run on the
        producing thread/event and must not block.  Subscribing the same
        fn twice is a no-op (it fires once per append, not twice)."""
        with self._subs_lock:
            if fn not in self._subs:
                self._subs[fn] = None
                self._subs_cache = tuple(self._subs)

    def unsubscribe(self, fn) -> None:
        """Remove ``fn``; unknown subscribers are tolerated."""
        with self._subs_lock:
            if fn in self._subs:
                del self._subs[fn]
                self._subs_cache = tuple(self._subs)

    def _honor_visibility(self) -> bool:
        """WAN-shaped visibility times are enforced when waiting for them
        is free: either the shaper really sleeps (live demo) or the clock
        is virtual (emulation, where time jumps to ``ready_at``).  With a
        real clock and ``sleep=False`` the latency is accounted in the
        metrics only — the seed's fast mode — so messages stay immediately
        visible."""
        return self.shaper is not None and (self.shaper.sleep
                                            or self._clock.virtual)

    @property
    def n_partitions(self) -> int:
        return len(self.partitions)

    # -- producer side ---------------------------------------------------

    def produce(self, payload: Any, *, key: Optional[str] = None,
                partition: Optional[int] = None,
                msg_id: Optional[str] = None) -> Message:
        raw = _serialize(payload)
        if msg_id is None:
            msg_id = f"{self.name}-{next(_msg_counter)}"
        if partition is None:
            if key is not None:
                partition = hash(key) % self.n_partitions
            else:
                partition = next(self._rr) % self.n_partitions
        msg = Message(msg_id=msg_id, key=key, raw=raw, partition=partition)
        now = self._clock.now()
        self.metrics.stamp(msg_id, "produced", bytes=msg.nbytes,
                           partition=partition)
        delay = 0.0
        if self.shaper is not None:
            delay = self.shaper.delay_for(msg.nbytes, now)
            if self.shaper.sleep and delay > 0:
                self._clock.sleep(delay)
                delay = 0.0
        part = self.partitions[partition]
        if self.single_owner:
            part.append_unlocked(msg, now + delay)
        else:
            part.append(msg, now + delay)
        self.metrics.stamp(msg_id, "broker_in", wan_delay_s=delay)
        self.metrics.incr(f"topic.{self.name}.bytes_in", msg.nbytes)
        self.metrics.incr(f"topic.{self.name}.msgs_in")
        if delay > 0.0:
            # produce-side observation of the shaped hop (queueing + tx +
            # one-way latency) — what the ReAdvisor watches for link drift.
            # Only shaped topics ever grow the counter, and every shaped
            # message carries rtt/2 > 0, so msgs_in deltas are the matching
            # denominator for a windowed mean.
            self.metrics.incr(f"topic.{self.name}.wan_delay_s", delay)
        for fn in self._subs_cache:     # immutable snapshot: no lock/copy
            fn(partition, now + delay)
        return msg

    def inject(self, raw: bytes, *, msg_id: str, partition: int,
               ready_at: float, key: Optional[str] = None,
               produced_t: Optional[float] = None) -> Message:
        """Boundary-queue delivery for sharded DES runs: append an
        already-serialized message with an explicit visibility time.

        Unlike :meth:`produce` this charges **no** shaper delay and does
        **not** count ``bytes_in``/``msgs_in``/``broker_in`` — the shard
        that originally produced the message owns those stamps and
        counters, so cross-shard traffic is never double-counted.  When
        ``produced_t`` is given the message's ``produced`` stamp is
        re-created in this shard's registry at its original time, so
        end-to-end latency percentiles computed here match an unsharded
        run."""
        msg = Message(msg_id=msg_id, key=key, raw=raw, partition=partition)
        if produced_t is not None:
            self.metrics.stamp(msg_id, "produced", t=produced_t,
                               bytes=msg.nbytes, partition=partition)
        part = self.partitions[partition]
        if self.single_owner:
            part.append_unlocked(msg, ready_at)
        else:
            part.append(msg, ready_at)
        for fn in self._subs_cache:
            fn(partition, ready_at)
        return msg

    # -- consumer side -----------------------------------------------------

    def poll(self, partition: int, offset: int,
             timeout_s: float = 1.0) -> Optional[Message]:
        """Blocking fetch of the message at ``offset`` in ``partition``.
        Honors WAN-shaped visibility times (a message 'in flight' across the
        WAN is not yet visible) whenever waiting for them is free — see
        :meth:`_honor_visibility`."""
        part = self.partitions[partition]
        honor = self._honor_visibility()
        deadline = self._clock.now() + timeout_s
        with part.cond:
            while True:
                now = self._clock.now()
                idx = offset - part.base
                if idx < 0:
                    raise KeyError(
                        f"offset {offset} below log start {part.base} of "
                        f"{self.name}[{partition}] (truncated)")
                if idx < len(part.log):
                    ready = part.ready_at[idx]
                    if honor and now < ready:
                        if now >= deadline:
                            return None
                        self._clock.wait(part.cond,
                                         min(ready - now, deadline - now))
                        continue
                    msg = part.log[idx]
                    self.metrics.stamp(
                        msg.msg_id, "broker_out",
                        visible_at=ready)
                    return msg
                remaining = deadline - now
                if remaining <= 0:
                    return None
                self._clock.wait(part.cond, remaining)

    def poll_nowait(self, partition: int, offset: int
                    ) -> Tuple[Optional[Message], Optional[float]]:
        """Non-blocking fetch for event-driven consumers.  Returns
        ``(message, None)`` when the message is visible now,
        ``(None, ready_at)`` when it exists but is still crossing the WAN
        (retry at ``ready_at``), and ``(None, None)`` when nothing has been
        produced at this offset yet."""
        part = self.partitions[partition]
        if self.truncate_batch is not None:
            if self.single_owner:
                # single-owner fast path: truncation can only run on this
                # same thread, so the base-aware read needs no lock — this
                # elides the locked poll_nowait variant on the DES path
                return self._poll_nowait_at(part, partition, offset)
            # truncation compacts log/ready_at in place under part.cond;
            # the lock-free index dance below would race with it
            with part.cond:
                return self._poll_nowait_at(part, partition, offset)
        # lock-free: append() publishes ready_at before log, list reads
        # are atomic under the GIL, and base is pinned at 0 when truncation
        # is off — the event-driven hot path pays no lock
        log = part.log
        if offset >= len(log):
            return None, None
        ready = part.ready_at[offset]
        if self._honor_visibility() and self._clock.now() < ready:
            return None, ready
        msg = log[offset]
        self.metrics.stamp(msg.msg_id, "broker_out", visible_at=ready)
        return msg, None

    def _poll_nowait_at(self, part: _Partition, partition: int, offset: int
                        ) -> Tuple[Optional[Message], Optional[float]]:
        """Base-aware fetch; caller holds ``part.cond``."""
        idx = offset - part.base
        if idx < 0:
            raise KeyError(
                f"offset {offset} below log start {part.base} of "
                f"{self.name}[{partition}] (truncated)")
        if idx >= len(part.log):
            return None, None
        ready = part.ready_at[idx]
        if self._honor_visibility() and self._clock.now() < ready:
            return None, ready
        msg = part.log[idx]
        self.metrics.stamp(msg.msg_id, "broker_out", visible_at=ready)
        return msg, None

    def end_offsets(self) -> List[int]:
        return [p.base + len(p.log) for p in self.partitions]

    def log_start_offsets(self) -> List[int]:
        """First retained absolute offset per partition (Kafka's
        ``logStartOffset``); 0 until truncation reclaims a prefix."""
        return [p.base for p in self.partitions]

    def log_sizes(self) -> List[int]:
        """Messages currently held in memory per partition."""
        return [len(p.log) for p in self.partitions]

    @property
    def truncated_msgs(self) -> int:
        """Total messages reclaimed from this topic's logs."""
        return sum(p.truncated for p in self.partitions)

    # -- log truncation ----------------------------------------------------

    def _register_group(self, group: "ConsumerGroup") -> None:
        with self._subs_lock:
            if group not in self._groups:
                self._groups[group] = None
                self._groups_cache = tuple(self._groups)

    def on_truncate(self, fn) -> None:
        """Register ``fn(partition, msg_ids)`` to fire after a prefix of a
        partition log is reclaimed, with the reclaimed message ids.  Lets
        downstream bookkeeping (e.g. dedup sets keyed by msg_id) drop
        entries for messages that can never be redelivered.  Callbacks run
        on the committing thread/event and must not block."""
        with self._subs_lock:
            if fn not in self._trunc_cbs:
                self._trunc_cbs[fn] = None
                self._trunc_cbs_cache = tuple(self._trunc_cbs)

    def maybe_truncate(self, partition: int) -> int:
        """Reclaim the partition-log prefix below the group-minimum
        committed offset, if it has reached ``truncate_batch`` messages.
        Returns the number of messages reclaimed (0 when truncation is
        disabled, the batch threshold is not met, or no group exists —
        with no groups nothing is safely consumable, so nothing is
        dropped).  Absolute offsets are preserved: ``log[0]`` simply moves
        to ``base``, and a read below ``base`` raises."""
        if self.truncate_batch is None:
            return 0
        groups = self._groups_cache
        if not groups:
            return 0
        # int list reads are GIL-atomic; a stale value only under-truncates
        safe = min(g.committed[partition] for g in groups)
        part = self.partitions[partition]
        if self.single_owner:
            reclaim = safe - part.base
            if reclaim < self.truncate_batch:
                return 0
            reclaimed_ids = [m.msg_id for m in part.log[:reclaim]]
            del part.log[:reclaim]
            del part.ready_at[:reclaim]
            part.base = safe
            part.truncated += reclaim
        else:
            with part.cond:
                reclaim = safe - part.base
                if reclaim < self.truncate_batch:
                    return 0
                reclaimed_ids = [m.msg_id for m in part.log[:reclaim]]
                del part.log[:reclaim]
                del part.ready_at[:reclaim]
                part.base = safe
                part.truncated += reclaim
        self.metrics.incr(f"topic.{self.name}.truncated_msgs", reclaim)
        for fn in self._trunc_cbs_cache:
            fn(partition, reclaimed_ids)
        return reclaim


class ConsumerGroup:
    """Kafka-like consumer group: partition assignment + committed offsets.

    ``assign(consumer_id)`` splits partitions round-robin across registered
    consumers; on consumer failure, ``rebalance`` re-assigns its partitions
    and surviving consumers resume from the committed offsets (at-least-once
    delivery, like Kafka).
    """

    def __init__(self, topic: Topic, group_id: str = "default"):
        self.topic = topic
        self.group_id = group_id
        self._clock = topic._clock
        self._lock = threading.Lock()
        # a new group starts at the log-start offsets: everything still
        # retained replays (Kafka auto.offset.reset=earliest), truncated
        # prefixes are gone by definition.  Registration makes this
        # group's committed offsets part of the truncation safety bound.
        self.committed = list(topic.log_start_offsets())
        topic._register_group(self)
        # dict-keyed membership: O(1) join/leave at 1000s of consumers
        # (insertion-ordered, so round-robin assignment is deterministic)
        self._members: Dict[str, None] = {}
        self.assignment: Dict[str, List[int]] = {}

    @property
    def members(self) -> List[str]:
        return list(self._members)

    def join(self, consumer_id: str) -> List[int]:
        with self._lock:
            self._members[consumer_id] = None
            self._rebalance_locked()
            return list(self.assignment.get(consumer_id, []))

    def leave(self, consumer_id: str) -> None:
        with self._lock:
            self._members.pop(consumer_id, None)
            self._rebalance_locked()

    def _rebalance_locked(self) -> None:
        # builds a *fresh* dict of fresh lists every time, so snapshots
        # handed out by partitions_for stay valid across rebalances
        members = list(self._members)
        self.assignment = {m: [] for m in members}
        if not members:
            return
        n = len(members)
        for p in range(self.topic.n_partitions):
            self.assignment[members[p % n]].append(p)

    _NO_PARTITIONS: List[int] = []

    def partitions_for(self, consumer_id: str) -> List[int]:
        """Current assignment snapshot. Treat as read-only: rebalances
        replace (never mutate) the lists, so no per-call lock or copy."""
        asg = self.assignment.get(consumer_id)
        return asg if asg is not None else ConsumerGroup._NO_PARTITIONS

    def poll(self, consumer_id: str,
             timeout_s: float = 1.0) -> Optional[Message]:
        """Fetch the next uncommitted message from any assigned partition."""
        parts = self.partitions_for(consumer_id)
        deadline = self._clock.now() + timeout_s
        while self._clock.now() < deadline or timeout_s == 0:
            for p in parts:
                with self._lock:
                    off = self.committed[p]
                end = self.topic.partitions[p]
                if off < end.base + len(end.log):
                    msg = self.topic.poll(p, off, timeout_s=0.01)
                    if msg is not None:
                        self.topic.metrics.stamp(msg.msg_id, "consumed",
                                                 consumer=consumer_id)
                        return msg
            if timeout_s == 0:
                return None
            self._clock.sleep(0.001)
        return None

    def poll_nowait(self, consumer_id: str
                    ) -> Tuple[Optional[Message], Optional[float]]:
        """Event-driven fetch: the next uncommitted *visible* message from
        any assigned partition, or ``(None, earliest_ready_at)`` when
        everything pending is still crossing the WAN (``(None, None)`` when
        nothing is pending at all)."""
        next_ready: Optional[float] = None
        for p in self.partitions_for(consumer_id):
            off = self.committed[p]     # int list read: GIL-atomic
            msg, ready = self.topic.poll_nowait(p, off)
            if msg is not None:
                self.topic.metrics.stamp(msg.msg_id, "consumed",
                                         consumer=consumer_id)
                return msg, None
            if ready is not None:
                next_ready = ready if next_ready is None \
                    else min(next_ready, ready)
        return None, next_ready

    def commit(self, msg: Message) -> None:
        if self.topic.single_owner:
            p = msg.partition
            if msg.offset + 1 > self.committed[p]:
                self.committed[p] = msg.offset + 1
        else:
            with self._lock:
                self.committed[msg.partition] = max(
                    self.committed[msg.partition], msg.offset + 1)
        # outside the group lock: truncation takes partition locks and may
        # fire on_truncate callbacks into downstream bookkeeping
        self.topic.maybe_truncate(msg.partition)

    def lag(self) -> int:
        ends = self.topic.end_offsets()
        with self._lock:
            return sum(e - c for e, c in zip(ends, self.committed))


class Broker:
    """Named-topic registry — one Broker per (pilot-managed) brokering
    service. Plugin point: the paper swaps Kafka↔MQTT here; we ship the
    in-memory implementation and keep the API surface minimal so an MQTT/
    Kafka binding is a drop-in."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 clock: Optional[Clock] = None):
        self._clock = as_clock(clock)
        self.metrics = metrics or MetricsRegistry(clock=self._clock)
        self._topics: Dict[str, Topic] = {}
        self._lock = threading.Lock()

    def create_topic(self, name: str, n_partitions: int = 1,
                     shaper: Optional[WanShaper] = None,
                     truncate_batch: Optional[int] = None) -> Topic:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic {name!r} exists")
            t = Topic(name, n_partitions, self.metrics, shaper,
                      clock=self._clock, truncate_batch=truncate_batch)
            self._topics[name] = t
            return t

    def topic(self, name: str) -> Topic:
        return self._topics[name]

    def consumer_group(self, topic_name: str,
                       group_id: str = "default") -> ConsumerGroup:
        return ConsumerGroup(self.topic(topic_name), group_id)
