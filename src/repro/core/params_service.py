"""Parameter service — the paper's Redis analog (§II-B step 2.2).

"Further, it provides a Redis-based parameter server for sharing model
weights across the continuum." Model updates in the paper's experiments are
"managed via the parameter service".

Implementation: a versioned, thread-safe pytree store.

* ``publish(name, tree)`` — store a new version (monotonic version numbers);
  values are host-side numpy copies so publishers can keep mutating device
  arrays.
* ``fetch(name)`` / ``fetch_if_newer(name, have_version)`` — consumers poll
  for updates (the paper's model-update pattern: the inference task refreshes
  its model when the trainer publishes).
* ``subscribe(name, callback)`` — push notification within-process.
* ``place(name, sharding)`` — device_put the current version onto a pilot's
  mesh with the given sharding: the continuum broadcast (across the 'pod'
  axis on the multi-pod mesh, this is the DCN weight broadcast).

Versioning gives the same monotonic-read consistency Redis-with-version-keys
gives the paper; there is no cross-version tear because publish swaps the
whole tree atomically under the lock.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclass
class _Entry:
    version: int
    tree: Any
    published_at: float
    nbytes: int


def _to_host(tree):
    # np.array(copy=True): published versions must be snapshots, immune to
    # later in-place mutation by the publisher
    return jax.tree.map(lambda x: np.array(x, copy=True), tree)


def _tree_bytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


class ParameterService:
    def __init__(self, metrics=None):
        self._lock = threading.Lock()
        self._store: Dict[str, _Entry] = {}
        self._subs: Dict[str, List[Callable[[int, Any], None]]] = {}
        self.metrics = metrics

    def publish(self, name: str, tree: Any) -> int:
        host_tree = _to_host(tree)
        nbytes = _tree_bytes(host_tree)
        with self._lock:
            version = (self._store[name].version + 1
                       if name in self._store else 1)
            self._store[name] = _Entry(version, host_tree,
                                       time.monotonic(), nbytes)
            subs = list(self._subs.get(name, ()))
        if self.metrics is not None:
            self.metrics.incr(f"params.{name}.publishes")
            self.metrics.incr(f"params.{name}.bytes", nbytes)
        for cb in subs:
            cb(version, host_tree)
        return version

    def fetch(self, name: str) -> Tuple[int, Any]:
        with self._lock:
            if name not in self._store:
                raise KeyError(name)
            e = self._store[name]
            return e.version, e.tree

    def fetch_if_newer(self, name: str,
                       have_version: int) -> Optional[Tuple[int, Any]]:
        with self._lock:
            e = self._store.get(name)
            if e is None or e.version <= have_version:
                return None
            return e.version, e.tree

    def version(self, name: str) -> int:
        with self._lock:
            e = self._store.get(name)
            return e.version if e else 0

    def subscribe(self, name: str,
                  callback: Callable[[int, Any], None]) -> None:
        with self._lock:
            self._subs.setdefault(name, []).append(callback)

    def place(self, name: str, sharding) -> Tuple[int, Any]:
        """Fetch + device_put under ``sharding`` (a NamedSharding or a pytree
        of them) — the cross-continuum weight broadcast."""
        version, tree = self.fetch(name)
        if isinstance(sharding, (jax.sharding.NamedSharding,
                                 jax.sharding.SingleDeviceSharding)):
            placed = jax.tree.map(lambda x: jax.device_put(x, sharding),
                                  tree)
        else:
            placed = jax.tree.map(jax.device_put, tree, sharding)
        return version, placed

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._store)
