"""Pilot-Edge core: the paper's contribution as a composable JAX-hosted
framework layer.

Public API (Listing 1 & 2 of the paper):

* :class:`PilotManager` / :class:`ComputeResource` / :class:`Pilot` —
  resource acquisition (step 1),
* :class:`ContinuumPipeline` / :class:`StageSpec` — N-stage FaaS dataflow
  along the continuum; :class:`EdgeToCloudPipeline` — the paper's
  Listing-2 two-stage wrapper (step 2),
* :class:`Broker` / :class:`WanShaper` — pilot-managed brokering,
* :class:`ParameterService` — cross-continuum model sharing,
* :class:`PlacementEngine` / :class:`TaskProfile` — placement trade-offs,
* :class:`MetricsRegistry` — linked cross-component monitoring (step 3),
* :class:`TaskRuntime` — per-pilot execution with retries/stragglers,
* :class:`AutoScaler` / :func:`remesh_restart` — dynamism + fault tolerance.
"""
from repro.core.broker import Broker, ConsumerGroup, Message, Topic, WanShaper
from repro.core.elastic import AutoScaler, ScalePolicy, remesh_restart
from repro.core.executor import (Poll, Service, SimExecutor, Sleep,
                                 ThreadedExecutor)
from repro.core.faas import (ContinuumPipeline, EdgeToCloudPipeline,
                             PipelineResult, StageSpec)
from repro.core.monitoring import MetricsRegistry
from repro.core.params_service import ParameterService
from repro.core.pilot import (ComputeResource, Pilot, PilotError,
                              PilotManager, register_backend)
from repro.core.placement import (LinkModel, PlacementDecision,
                                  PlacementEngine, TaskProfile)
from repro.core.runtime import TaskContext, TaskFailed, TaskFuture, TaskRuntime
from repro.sim.clock import SimClock, SystemClock, as_clock

__all__ = [
    "SimClock", "SystemClock", "as_clock",
    "ThreadedExecutor", "SimExecutor", "Poll", "Service", "Sleep",
    "Broker", "ConsumerGroup", "Message", "Topic", "WanShaper",
    "AutoScaler", "ScalePolicy", "remesh_restart",
    "ContinuumPipeline", "StageSpec", "EdgeToCloudPipeline",
    "PipelineResult",
    "MetricsRegistry", "ParameterService",
    "ComputeResource", "Pilot", "PilotError", "PilotManager",
    "register_backend",
    "LinkModel", "PlacementDecision", "PlacementEngine", "TaskProfile",
    "TaskContext", "TaskFailed", "TaskFuture", "TaskRuntime",
]
