"""Placement engine (paper §II-B step 2.1).

"Pilot-Edge automatically handles task placements, i.e., the binding of a
task to a pilot" — "considering application-defined preferences (e.g., data
dependencies and preferred placements)".

The engine scores candidate pilots for a task from:

* **preference** — the application's preferred tier(s) (the paper's
  cloud-centric vs edge-centric vs hybrid deployment modalities),
* **data locality** — estimated bytes that must cross the continuum if the
  task lands on this pilot, charged at per-hop bandwidth (edge↔cloud rides
  the WAN; within a tier rides local links),
* **compute cost** — task FLOPs at the pilot's effective FLOP/s (an edge
  pilot is RasPi-class; a cloud mesh pilot aggregates its devices),
* **load** — outstanding tasks on the pilot's runtime.

Score = estimated completion time; lowest wins. This is exactly the paper's
experiment-driven trade-off (Fig 3: k-means is transfer-bound so geo
placement halves throughput; autoencoders are compute-bound so the network
"is not the bottleneck") turned into a cost model, and it is what the
EdgeToCloudPipeline uses when the application passes ``placement='auto'``.

Every number the engine prices with flows from the unified cost subsystem
(:mod:`repro.cost`): link bandwidths/latencies come from the shared
:data:`~repro.cost.profiles.WAN_BANDS` table (``DEFAULT_LINKS`` below is an
import-time snapshot of it, pinned equal by a regression test) and tier
FLOP rates come from the continuum profile's device
specs — there are no module-level cost constants here any more.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

from repro.core.pilot import Pilot
from repro.cost.model import CostModel, default_cost_model
from repro.cost.profiles import DEFAULT_PROFILE, LinkModel  # noqa: F401

# the shared link table (edge↔cloud/hpc ride the paper's 10 Mbit/s iPerf
# WAN band, cloud↔hpc a fat datacenter link) — an import-time snapshot of
# the continuum profile, pinned equal to sim.scenarios' WAN table by a
# regression test
DEFAULT_LINKS: Dict[Tuple[str, str], LinkModel] = dict(
    DEFAULT_PROFILE.links)


def link_between(a: str, b: str,
                 links: Dict[Tuple[str, str], LinkModel],
                 profile=DEFAULT_PROFILE) -> LinkModel:
    """Resolve the link between two tiers: the explicit table first, then
    the profile's intra-tier / fallback links."""
    if a == b:
        return profile.link(a, a)
    return links.get((a, b)) or links.get((b, a)) or profile.link(a, b)


@dataclass(frozen=True)
class TaskProfile:
    """What the placement engine knows about a task. ``flops`` is
    peak-rate-equivalent work (a calibrated ``ModelCost`` folds kernel
    efficiency into its ``effective_flops_per_point``)."""
    flops: float = 0.0                 # estimated compute
    input_bytes: float = 0.0           # bytes it must pull
    input_tier: str = "edge"           # where the input currently lives
    output_bytes: float = 0.0
    output_tier: Optional[str] = None  # where the output must land
    preferred_tiers: Sequence[str] = ()
    memory_gb: float = 0.0
    precision: str = "fp32"            # kernel precision variant (fp32 |
    #                                    bf16 | int8): compute is priced at
    #                                    the pilot tier's precision peak


@dataclass
class PlacementDecision:
    pilot: Pilot
    est_time_s: float
    breakdown: Dict[str, float] = field(default_factory=dict)


class PlacementEngine:
    """Scores pilots through a :class:`~repro.cost.model.CostModel`.

    Any tier in the continuum profile is scored at *its own* device rate
    — a fog pilot is priced as fog hardware, not silently as cloud (the
    historical edge-vs-everything-else branching mispriced every
    intermediate tier at cloud rates).

    ``links`` overrides the link table (e.g. one WAN band of the Fig-3
    sweep); ``edge_flops``/``device_flops`` override the edge and
    cloud/hpc rates (back-compat knobs — prefer passing a ``cost_model``
    built on a custom :class:`~repro.cost.profiles.ContinuumProfile`)."""

    def __init__(self, links: Optional[Dict] = None,
                 edge_flops: Optional[float] = None,
                 device_flops: Optional[float] = None,
                 cost_model: Optional[CostModel] = None):
        self.cost = cost_model or default_cost_model()
        self.links = dict(self.cost.links if links is None else links)
        self._tier_overrides: Dict[str, float] = {}
        if edge_flops is not None:
            self._tier_overrides["edge"] = edge_flops
        if device_flops is not None:
            self._tier_overrides["cloud"] = device_flops
            self._tier_overrides["hpc"] = device_flops
        self.edge_flops = (edge_flops if edge_flops is not None
                           else self.cost.tier_flops("edge"))
        self.device_flops = (device_flops if device_flops is not None
                             else self.cost.tier_flops("cloud"))

    def tier_rate(self, tier: str, precision: str = "fp32") -> float:
        """Per-device peak FLOP/s of a tier at a kernel precision: the
        override when set (overrides are fp32 back-compat knobs and stay
        unscaled), else the profile's device rate × its precision
        speedup.  Tiers the profile doesn't know price conservatively at
        the *slowest* known tier's rate — an optimistic (fast) guess
        would bias auto-placement onto unmodeled tiers."""
        rate = self._tier_overrides.get(tier)
        if rate is not None:
            return rate
        try:
            return self.cost.tier_flops(tier, 1, precision)
        except KeyError:
            rates = [tp.device.peak_flops
                     for tp in self.cost.profile.tiers.values()]
            return min(rates) if rates else self.device_flops

    def pilot_flops(self, pilot: Pilot, precision: str = "fp32") -> float:
        if pilot.mesh is not None:
            # mesh pilots aggregate cloud-class accelerator devices
            return self.tier_rate(pilot.tier, precision) * len(pilot.devices)
        return self.tier_rate(pilot.tier, precision) \
            * pilot.resource.n_workers

    def estimate(self, task: TaskProfile, pilot: Pilot,
                 queue_depth: int = 0) -> PlacementDecision:
        profile = self.cost.profile
        move_in = link_between(task.input_tier, pilot.tier, self.links,
                               profile)
        t_in = (task.input_bytes / move_in.bandwidth + move_in.latency_s
                if task.input_bytes else 0.0)
        t_out = 0.0
        if task.output_bytes and task.output_tier:
            move_out = link_between(pilot.tier, task.output_tier,
                                    self.links, profile)
            t_out = (task.output_bytes / move_out.bandwidth
                     + move_out.latency_s)
        t_compute = task.flops / max(
            self.pilot_flops(pilot, task.precision), 1.0)
        t_queue = queue_depth * max(t_compute, 1e-6)
        penalty = 0.0
        if task.preferred_tiers and pilot.tier not in task.preferred_tiers:
            penalty = 10.0 * (t_in + t_compute + t_out + 1e-3)
        if (task.memory_gb and pilot.resource.memory_gb
                and task.memory_gb > pilot.resource.memory_gb):
            penalty += 1e6                     # doesn't fit — effectively veto
        total = t_in + t_compute + t_out + t_queue + penalty
        return PlacementDecision(
            pilot=pilot, est_time_s=total,
            breakdown={"t_in": t_in, "t_compute": t_compute, "t_out": t_out,
                       "t_queue": t_queue, "penalty": penalty})

    def place(self, task: TaskProfile, pilots: Sequence[Pilot],
              queue_depths: Optional[Dict[str, int]] = None
              ) -> PlacementDecision:
        if not pilots:
            raise ValueError("no candidate pilots")
        queue_depths = queue_depths or {}
        decisions = [
            self.estimate(task, p, queue_depths.get(p.pilot_id, 0))
            for p in pilots if p.state == "active"]
        if not decisions:
            raise ValueError("no active pilots")
        return min(decisions, key=lambda d: d.est_time_s)

    def compare_tiers(self, task: TaskProfile,
                      pilots: Sequence[Pilot]) -> Dict[str, float]:
        """Per-tier estimated times — the paper's Fig 3 style trade-off
        table, exposed to applications for placement evaluation."""
        out: Dict[str, float] = {}
        for p in pilots:
            d = self.estimate(task, p)
            if p.tier not in out or d.est_time_s < out[p.tier]:
                out[p.tier] = d.est_time_s
        return out
