"""Placement engine (paper §II-B step 2.1).

"Pilot-Edge automatically handles task placements, i.e., the binding of a
task to a pilot" — "considering application-defined preferences (e.g., data
dependencies and preferred placements)".

The engine scores candidate pilots for a task from:

* **preference** — the application's preferred tier(s) (the paper's
  cloud-centric vs edge-centric vs hybrid deployment modalities),
* **data locality** — estimated bytes that must cross the continuum if the
  task lands on this pilot, charged at per-hop bandwidth (edge↔cloud rides
  the WAN; within a tier rides local links),
* **compute cost** — task FLOPs at the pilot's effective FLOP/s (an edge
  pilot is RasPi-class; a cloud mesh pilot aggregates its devices),
* **load** — outstanding tasks on the pilot's runtime.

Score = estimated completion time; lowest wins. This is exactly the paper's
experiment-driven trade-off (Fig 3: k-means is transfer-bound so geo
placement halves throughput; autoencoders are compute-bound so the network
"is not the bottleneck") turned into a cost model, and it is what the
EdgeToCloudPipeline uses when the application passes ``placement='auto'``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.pilot import Pilot


@dataclass(frozen=True)
class LinkModel:
    """Bandwidth (bytes/s) + latency between tiers."""
    bandwidth: float
    latency_s: float = 0.0


# defaults: WAN for edge<->cloud (paper's iPerf band), fast links intra-tier
DEFAULT_LINKS: Dict[Tuple[str, str], LinkModel] = {
    ("edge", "cloud"): LinkModel(bandwidth=10e6, latency_s=0.150),
    ("edge", "hpc"): LinkModel(bandwidth=10e6, latency_s=0.150),
    ("cloud", "hpc"): LinkModel(bandwidth=1e9, latency_s=0.020),
}


def link_between(a: str, b: str,
                 links: Dict[Tuple[str, str], LinkModel]) -> LinkModel:
    if a == b:
        return LinkModel(bandwidth=10e9, latency_s=0.0)
    return links.get((a, b)) or links.get((b, a)) or \
        LinkModel(bandwidth=10e6, latency_s=0.2)


# effective per-pilot compute (FLOP/s). Edge = RasPi-class (paper: 1 core /
# 4 GB Dask task). Cloud devices get a per-device rate.
EDGE_FLOPS = 5e9
DEVICE_FLOPS = 50e9           # host CPU device (the container's reality)


@dataclass(frozen=True)
class TaskProfile:
    """What the placement engine knows about a task."""
    flops: float = 0.0                 # estimated compute
    input_bytes: float = 0.0           # bytes it must pull
    input_tier: str = "edge"           # where the input currently lives
    output_bytes: float = 0.0
    output_tier: Optional[str] = None  # where the output must land
    preferred_tiers: Sequence[str] = ()
    memory_gb: float = 0.0


@dataclass
class PlacementDecision:
    pilot: Pilot
    est_time_s: float
    breakdown: Dict[str, float] = field(default_factory=dict)


class PlacementEngine:
    def __init__(self, links: Optional[Dict] = None,
                 edge_flops: float = EDGE_FLOPS,
                 device_flops: float = DEVICE_FLOPS):
        self.links = dict(DEFAULT_LINKS if links is None else links)
        self.edge_flops = edge_flops
        self.device_flops = device_flops

    def pilot_flops(self, pilot: Pilot) -> float:
        if pilot.mesh is not None:
            return self.device_flops * len(pilot.devices)
        if pilot.tier == "edge":
            return self.edge_flops * pilot.resource.n_workers
        return self.device_flops * pilot.resource.n_workers

    def estimate(self, task: TaskProfile, pilot: Pilot,
                 queue_depth: int = 0) -> PlacementDecision:
        move_in = link_between(task.input_tier, pilot.tier, self.links)
        t_in = (task.input_bytes / move_in.bandwidth + move_in.latency_s
                if task.input_bytes else 0.0)
        t_out = 0.0
        if task.output_bytes and task.output_tier:
            move_out = link_between(pilot.tier, task.output_tier, self.links)
            t_out = (task.output_bytes / move_out.bandwidth
                     + move_out.latency_s)
        t_compute = task.flops / max(self.pilot_flops(pilot), 1.0)
        t_queue = queue_depth * max(t_compute, 1e-6)
        penalty = 0.0
        if task.preferred_tiers and pilot.tier not in task.preferred_tiers:
            penalty = 10.0 * (t_in + t_compute + t_out + 1e-3)
        if (task.memory_gb and pilot.resource.memory_gb
                and task.memory_gb > pilot.resource.memory_gb):
            penalty += 1e6                     # doesn't fit — effectively veto
        total = t_in + t_compute + t_out + t_queue + penalty
        return PlacementDecision(
            pilot=pilot, est_time_s=total,
            breakdown={"t_in": t_in, "t_compute": t_compute, "t_out": t_out,
                       "t_queue": t_queue, "penalty": penalty})

    def place(self, task: TaskProfile, pilots: Sequence[Pilot],
              queue_depths: Optional[Dict[str, int]] = None
              ) -> PlacementDecision:
        if not pilots:
            raise ValueError("no candidate pilots")
        queue_depths = queue_depths or {}
        decisions = [
            self.estimate(task, p, queue_depths.get(p.pilot_id, 0))
            for p in pilots if p.state == "active"]
        if not decisions:
            raise ValueError("no active pilots")
        return min(decisions, key=lambda d: d.est_time_s)

    def compare_tiers(self, task: TaskProfile,
                      pilots: Sequence[Pilot]) -> Dict[str, float]:
        """Per-tier estimated times — the paper's Fig 3 style trade-off
        table, exposed to applications for placement evaluation."""
        out: Dict[str, float] = {}
        for p in pilots:
            d = self.estimate(task, p)
            if p.tier not in out or d.est_time_s < out[p.tier]:
                out[p.tier] = d.est_time_s
        return out
