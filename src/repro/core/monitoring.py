"""Cross-component monitoring (paper §II-B step 3, §III.1).

The paper: "The framework captures and links comprehensive metrics across all
involved components, particularly the edge data generator, broker, and cloud
processing services ... This data allows the easy identification of
bottlenecks."

We reproduce that with a process-wide :class:`MetricsRegistry`. Every message
carries a unique ``msg_id``; each component stamps events
(``produced`` / ``broker_in`` / ``broker_out`` / ``consumed`` /
``processed``) against that id, so end-to-end latency decomposes into
per-hop latencies exactly like the paper's linked metrics. Counters and
gauges cover throughput and resource accounting (bytes through the broker,
task retries, straggler re-executions).

Thread-safe: producers/consumers/runtimes stamp from their own threads.

Two storage modes:

* **exact** (default) — one :class:`MessageTrace` kept per message for the
  whole run.  Arbitrary spans, exact percentiles, and the mode every
  committed golden was pinned under.  Memory grows linearly with run
  length (the dominant RSS term at 1M+ messages).
* **streaming** (``MetricsRegistry(streaming=True)``) — traces live only
  while a message is *in flight*: when its terminal ``processed`` stamp
  lands (or the bounded pending window evicts it), the trace's per-hop
  and end-to-end spans are folded into fixed-bucket log-spaced latency
  sketches (:class:`LatencySketch`) and the trace is dropped.  Memory is
  O(in-flight + sketch buckets), independent of run length; percentiles
  are bucket-resolution approximations (≲4 % relative error) instead of
  exact order statistics.  Aggregation stays deterministic: sketches are
  a pure function of the folded spans.
"""
from __future__ import annotations

import math
import statistics
import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.sim.clock import NULL_LOCK, as_clock


@dataclass
class MessageTrace:
    """Linked per-message timestamps across components (seconds)."""
    msg_id: str
    stamps: Dict[str, float] = field(default_factory=dict)
    meta: Dict[str, float] = field(default_factory=dict)

    def span(self, start: str, end: str) -> Optional[float]:
        if start in self.stamps and end in self.stamps:
            return self.stamps[end] - self.stamps[start]
        return None


# canonical event names, in pipeline order
EVENTS = ("produced", "broker_in", "broker_out", "consumed", "processed")

# the spans folded into sketches when a trace is retired in streaming
# mode: every consecutive hop plus the end-to-end pair
_SKETCH_SPANS: Tuple[Tuple[str, str], ...] = (
    *zip(EVENTS[:-1], EVENTS[1:]), (EVENTS[0], EVENTS[-1]))


class LatencySketch:
    """Fixed-memory latency distribution: log-spaced bucket histogram.

    Buckets span ``[LO, HI)`` seconds at ``PER_DECADE`` buckets per decade
    (relative bucket width ``10**(1/PER_DECADE) - 1`` ≈ 3.7 %), with an
    underflow bucket below ``LO`` and an overflow bucket above ``HI``.
    ``count``/``total``/``min``/``max`` are tracked exactly, so ``mean``
    is exact and only the interior percentiles are bucket-resolution
    approximations.  Deterministic: the state is a pure function of the
    added values (no sampling, no randomized compaction)."""

    LO = 1e-7                      # 100 ns: below any virtual hop latency
    HI = 1e6                       # ~11.6 virtual days
    PER_DECADE = 64

    __slots__ = ("counts", "count", "total", "min", "max")

    _N_INTERIOR = int(round((math.log10(HI) - math.log10(LO)) * PER_DECADE))
    _LOG_LO = math.log10(LO)

    def __init__(self):
        # [0] underflow, [1.._N_INTERIOR] interior, [-1] overflow
        self.counts = [0] * (self._N_INTERIOR + 2)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        self.count += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if x < self.LO:
            idx = 0
        else:
            idx = 1 + int((math.log10(x) - self._LOG_LO) * self.PER_DECADE)
            if idx > self._N_INTERIOR:
                idx = self._N_INTERIOR + 1
        self.counts[idx] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket holding the ``q``-quantile (``q`` in
        [0, 1]); exact ``min``/``max`` are returned at the extremes and
        every estimate is clamped into ``[min, max]``."""
        if self.count == 0:
            return 0.0
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        # the rank the exact-mode percentile uses: sorted()[int(q * n)]
        rank = min(self.count - 1, int(q * self.count))
        cum = 0
        for idx, c in enumerate(self.counts):
            cum += c
            if cum > rank:
                if idx == 0:
                    edge = self.LO
                else:
                    edge = 10.0 ** (self._LOG_LO
                                    + idx / self.PER_DECADE)
                return min(max(edge, self.min), self.max)
        return self.max              # unreachable (cum ends at count)

    # -- cross-process merging (sharded DES) ------------------------------

    def state(self) -> dict:
        """Picklable snapshot for shipping a worker's sketch over a pipe."""
        return {"counts": list(self.counts), "count": self.count,
                "total": self.total, "min": self.min, "max": self.max}

    @classmethod
    def from_state(cls, st: dict) -> "LatencySketch":
        sk = cls()
        sk.counts = list(st["counts"])
        sk.count = int(st["count"])
        sk.total = float(st["total"])
        sk.min = float(st["min"])
        sk.max = float(st["max"])
        return sk

    def merge(self, other: "LatencySketch") -> None:
        """Fold another sketch in.  Bucket counts, count, min and max merge
        exactly, so merged percentiles are bit-identical to a single sketch
        fed the union of values; only ``total`` (hence ``mean``) depends on
        float summation order."""
        if len(other.counts) != len(self.counts):
            raise ValueError("cannot merge sketches with different layouts")
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.count += other.count
        self.total += other.total
        if other.min < self.min:
            self.min = other.min
        if other.max > self.max:
            self.max = other.max


class _EventStats:
    """Running per-event aggregates (streaming mode): stamp count,
    first/last stamp time, and bytes attributed to the event."""

    __slots__ = ("count", "first", "last", "bytes")

    def __init__(self):
        self.count = 0
        self.first = math.inf
        self.last = -math.inf
        self.bytes = 0.0


class MetricsRegistry:
    """Process-wide registry: message traces + counters + gauges.

    One registry per pipeline run; injected into broker/runtime/pipeline so
    all components stamp into the same store (the paper's "unique job
    identifier ensures that progress and errors can be consistently
    tracked").

    ``streaming=True`` decouples registry memory from run length: traces
    are retired into :class:`LatencySketch` aggregates at their
    ``processed`` stamp (or when the ``max_pending`` in-flight window
    evicts them — intermediate-hop messages of multi-stage pipelines
    never see ``processed`` and leave through the window), so only
    in-flight messages occupy memory.  ``summary``/``percentile``/
    ``per_hop_latency``/``throughput``/``first_stamp``/``last_stamp``
    keep working (sketch-backed); the exact per-message ``latencies``/
    ``trace`` views are unavailable and raise.
    """

    def __init__(self, clock=None, *, streaming: bool = False,
                 max_pending: int = 100_000):
        # accepts a Clock object, a bare now() callable (seed API), or None
        self.clock = as_clock(clock)
        self._clock = self.clock.now
        self._lock = threading.Lock()
        self.streaming = streaming
        self.max_pending = max_pending
        self._traces: Dict[str, MessageTrace] = {}
        self._counters: Dict[str, float] = defaultdict(float)
        self._events: List[dict] = []
        # streaming mode state (untouched in exact mode)
        self._sketches: Dict[Tuple[str, str], LatencySketch] = {}
        self._estats: Dict[str, _EventStats] = {}
        self._retired = 0

    # -- message lifecycle ---------------------------------------------------

    def elide_lock(self, elide: bool = True) -> None:
        """Swap the registry lock for a no-op (``elide=True``) or restore a
        real :class:`threading.Lock`.  Only the single-owner DES path may
        elide: the SimExecutor is the sole thread touching the registry, so
        the lock acquire/release per stamp (5 stamps/message) is pure
        overhead there."""
        self._lock = NULL_LOCK if elide else threading.Lock()

    def stamp(self, msg_id: str, event: str, *,
              t: Optional[float] = None, **meta) -> float:
        """Stamp ``event`` on ``msg_id`` at the clock's current time, or at
        an explicit ``t`` (used by sharded runs to re-stamp a boundary
        message at its original production time in the receiving shard)."""
        if t is None:
            t = self._clock()
        with self._lock:
            tr = self._traces.setdefault(msg_id, MessageTrace(msg_id))
            if self.streaming and event not in tr.stamps:
                es = self._estats.get(event)
                if es is None:
                    self._estats[event] = es = _EventStats()
                es.count += 1
                if t < es.first:
                    es.first = t
                if t > es.last:
                    es.last = t
                es.bytes += meta.get("bytes", 0.0)
            tr.stamps[event] = t
            tr.meta.update(meta)
            if self.streaming:
                if event == EVENTS[-1]:
                    self._retire(self._traces.pop(msg_id))
                elif len(self._traces) > self.max_pending:
                    # FIFO window: retire the oldest in-flight trace with
                    # whatever spans it has (dicts are insertion-ordered)
                    oldest = next(iter(self._traces))
                    self._retire(self._traces.pop(oldest))
        return t

    def _retire(self, tr: MessageTrace) -> None:
        """Fold a finished (or window-evicted) trace's spans into the
        sketches and let the trace go.  Caller holds the lock."""
        self._retired += 1
        stamps = tr.stamps
        for a, b in _SKETCH_SPANS:
            ta = stamps.get(a)
            if ta is None:
                continue
            tb = stamps.get(b)
            if tb is None:
                continue
            sk = self._sketches.get((a, b))
            if sk is None:
                self._sketches[(a, b)] = sk = LatencySketch()
            sk.add(tb - ta)

    def trace(self, msg_id: str) -> Optional[MessageTrace]:
        with self._lock:
            return self._traces.get(msg_id)

    # -- counters / events ----------------------------------------------------

    def incr(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] += value

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def event(self, kind: str, **data) -> None:
        with self._lock:
            self._events.append({"kind": kind, "t": self._clock(), **data})

    def events(self, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            if kind is None:
                return list(self._events)
            return [e for e in self._events if e["kind"] == kind]

    # -- aggregation (the paper's Fig 2/3 metrics) ----------------------------

    def latencies(self, start: str = "produced",
                  end: str = "processed") -> List[float]:
        if self.streaming:
            raise RuntimeError(
                "MetricsRegistry(streaming=True) does not keep per-message "
                "latencies; use summary()/percentile()/per_hop_latency()")
        with self._lock:
            out = []
            for tr in self._traces.values():
                s = tr.span(start, end)
                if s is not None:
                    out.append(s)
            return out

    def _sketch(self, start: str, end: str) -> Optional[LatencySketch]:
        """Streaming-mode sketch for a span, or None if never observed.
        Only the spans in ``_SKETCH_SPANS`` are retained."""
        with self._lock:
            return self._sketches.get((start, end))

    def percentile(self, q: float, start: str = "produced",
                   end: str = "processed") -> float:
        """``q``-quantile of the span latency, in either mode.

        Exact order statistic in exact mode; bucket-edge estimate in
        streaming mode (the two agree to within the sketch's ~3.7 %
        bucket width)."""
        if self.streaming:
            sk = self._sketch(start, end)
            return sk.percentile(q) if sk is not None else 0.0
        lat = sorted(self.latencies(start, end))
        if not lat:
            return 0.0
        return lat[min(len(lat) - 1, int(q * len(lat)))]

    def summary(self, start: str = "produced",
                end: str = "processed") -> Dict[str, float]:
        if self.streaming:
            sk = self._sketch(start, end)
            if sk is None or sk.count == 0:
                return {"count": 0}
            return {
                "count": sk.count,
                "mean_s": sk.mean,
                "p50_s": sk.percentile(0.50),
                "p95_s": sk.percentile(0.95),
                "max_s": sk.max,
            }
        lat = self.latencies(start, end)
        if not lat:
            return {"count": 0}
        lat.sort()
        n = len(lat)
        return {
            "count": n,
            "mean_s": statistics.fmean(lat),
            "p50_s": lat[n // 2],
            "p95_s": lat[min(n - 1, int(0.95 * n))],
            "max_s": lat[-1],
        }

    def first_stamp(self, event: str) -> Optional[float]:
        """Earliest timestamp of ``event`` across all traces."""
        with self._lock:
            if self.streaming:
                es = self._estats.get(event)
                return es.first if es is not None else None
            ts = [tr.stamps[event] for tr in self._traces.values()
                  if event in tr.stamps]
        return min(ts) if ts else None

    def last_stamp(self, event: str) -> Optional[float]:
        """Latest timestamp of ``event`` across all traces."""
        with self._lock:
            if self.streaming:
                es = self._estats.get(event)
                return es.last if es is not None else None
            ts = [tr.stamps[event] for tr in self._traces.values()
                  if event in tr.stamps]
        return max(ts) if ts else None

    def event_count(self, event: str) -> int:
        """Number of distinct messages stamped with ``event`` (both modes)."""
        with self._lock:
            if self.streaming:
                es = self._estats.get(event)
                return es.count if es is not None else 0
            return sum(1 for tr in self._traces.values()
                       if event in tr.stamps)

    def throughput(self, event: str = "processed") -> Dict[str, float]:
        """Messages/s and bytes/s over the observed window of ``event``."""
        with self._lock:
            if self.streaming:
                es = self._estats.get(event)
                if es is None or es.count < 2:
                    n = es.count if es is not None else 0
                    return {"msgs_per_s": 0.0, "bytes_per_s": 0.0,
                            "count": n}
                dt = max(es.last - es.first, 1e-9)
                return {"msgs_per_s": es.count / dt,
                        "bytes_per_s": es.bytes / dt, "count": es.count}
            ts = [tr.stamps[event] for tr in self._traces.values()
                  if event in tr.stamps]
            nbytes = sum(tr.meta.get("bytes", 0.0)
                         for tr in self._traces.values()
                         if event in tr.stamps)
        if len(ts) < 2:
            return {"msgs_per_s": 0.0, "bytes_per_s": 0.0, "count": len(ts)}
        dt = max(max(ts) - min(ts), 1e-9)
        return {"msgs_per_s": len(ts) / dt, "bytes_per_s": nbytes / dt,
                "count": len(ts)}

    def per_hop_latency(self) -> Dict[str, Dict[str, float]]:
        """Decomposed latency between consecutive pipeline events — the
        paper's bottleneck-identification view (e.g. broker faster than the
        consuming processing tasks)."""
        out = {}
        if self.streaming:
            for a, b in zip(EVENTS[:-1], EVENTS[1:]):
                sk = self._sketch(a, b)
                if sk is not None and sk.count:
                    out[f"{a}->{b}"] = {
                        "mean_s": sk.mean, "max_s": sk.max,
                        "count": sk.count}
            return out
        for a, b in zip(EVENTS[:-1], EVENTS[1:]):
            lat = self.latencies(a, b)
            if lat:
                out[f"{a}->{b}"] = {
                    "mean_s": statistics.fmean(lat),
                    "max_s": max(lat), "count": len(lat)}
        return out

    @property
    def pending_traces(self) -> int:
        """In-flight (unretired) trace count — bounded by ``max_pending``
        in streaming mode, the full run in exact mode."""
        with self._lock:
            return len(self._traces)

    @property
    def retired_traces(self) -> int:
        """Traces folded into sketches (streaming mode only)."""
        with self._lock:
            return self._retired
