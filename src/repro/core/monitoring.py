"""Cross-component monitoring (paper §II-B step 3, §III.1).

The paper: "The framework captures and links comprehensive metrics across all
involved components, particularly the edge data generator, broker, and cloud
processing services ... This data allows the easy identification of
bottlenecks."

We reproduce that with a process-wide :class:`MetricsRegistry`. Every message
carries a unique ``msg_id``; each component stamps events
(``produced`` / ``broker_in`` / ``broker_out`` / ``consumed`` /
``processed``) against that id, so end-to-end latency decomposes into
per-hop latencies exactly like the paper's linked metrics. Counters and
gauges cover throughput and resource accounting (bytes through the broker,
task retries, straggler re-executions).

Thread-safe: producers/consumers/runtimes stamp from their own threads.
"""
from __future__ import annotations

import statistics
import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.sim.clock import as_clock


@dataclass
class MessageTrace:
    """Linked per-message timestamps across components (seconds)."""
    msg_id: str
    stamps: Dict[str, float] = field(default_factory=dict)
    meta: Dict[str, float] = field(default_factory=dict)

    def span(self, start: str, end: str) -> Optional[float]:
        if start in self.stamps and end in self.stamps:
            return self.stamps[end] - self.stamps[start]
        return None


# canonical event names, in pipeline order
EVENTS = ("produced", "broker_in", "broker_out", "consumed", "processed")


class MetricsRegistry:
    """Process-wide registry: message traces + counters + gauges.

    One registry per pipeline run; injected into broker/runtime/pipeline so
    all components stamp into the same store (the paper's "unique job
    identifier ensures that progress and errors can be consistently
    tracked").
    """

    def __init__(self, clock=None):
        # accepts a Clock object, a bare now() callable (seed API), or None
        self.clock = as_clock(clock)
        self._clock = self.clock.now
        self._lock = threading.Lock()
        self._traces: Dict[str, MessageTrace] = {}
        self._counters: Dict[str, float] = defaultdict(float)
        self._events: List[dict] = []

    # -- message lifecycle ---------------------------------------------------

    def stamp(self, msg_id: str, event: str, **meta) -> float:
        t = self._clock()
        with self._lock:
            tr = self._traces.setdefault(msg_id, MessageTrace(msg_id))
            tr.stamps[event] = t
            tr.meta.update(meta)
        return t

    def trace(self, msg_id: str) -> Optional[MessageTrace]:
        with self._lock:
            return self._traces.get(msg_id)

    # -- counters / events ----------------------------------------------------

    def incr(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] += value

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def event(self, kind: str, **data) -> None:
        with self._lock:
            self._events.append({"kind": kind, "t": self._clock(), **data})

    def events(self, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            if kind is None:
                return list(self._events)
            return [e for e in self._events if e["kind"] == kind]

    # -- aggregation (the paper's Fig 2/3 metrics) ----------------------------

    def latencies(self, start: str = "produced",
                  end: str = "processed") -> List[float]:
        with self._lock:
            out = []
            for tr in self._traces.values():
                s = tr.span(start, end)
                if s is not None:
                    out.append(s)
            return out

    def summary(self, start: str = "produced",
                end: str = "processed") -> Dict[str, float]:
        lat = self.latencies(start, end)
        if not lat:
            return {"count": 0}
        lat.sort()
        n = len(lat)
        return {
            "count": n,
            "mean_s": statistics.fmean(lat),
            "p50_s": lat[n // 2],
            "p95_s": lat[min(n - 1, int(0.95 * n))],
            "max_s": lat[-1],
        }

    def first_stamp(self, event: str) -> Optional[float]:
        """Earliest timestamp of ``event`` across all traces."""
        with self._lock:
            ts = [tr.stamps[event] for tr in self._traces.values()
                  if event in tr.stamps]
        return min(ts) if ts else None

    def last_stamp(self, event: str) -> Optional[float]:
        """Latest timestamp of ``event`` across all traces."""
        with self._lock:
            ts = [tr.stamps[event] for tr in self._traces.values()
                  if event in tr.stamps]
        return max(ts) if ts else None

    def throughput(self, event: str = "processed") -> Dict[str, float]:
        """Messages/s and bytes/s over the observed window of ``event``."""
        with self._lock:
            ts = [tr.stamps[event] for tr in self._traces.values()
                  if event in tr.stamps]
            nbytes = sum(tr.meta.get("bytes", 0.0)
                         for tr in self._traces.values()
                         if event in tr.stamps)
        if len(ts) < 2:
            return {"msgs_per_s": 0.0, "bytes_per_s": 0.0, "count": len(ts)}
        dt = max(max(ts) - min(ts), 1e-9)
        return {"msgs_per_s": len(ts) / dt, "bytes_per_s": nbytes / dt,
                "count": len(ts)}

    def per_hop_latency(self) -> Dict[str, Dict[str, float]]:
        """Decomposed latency between consecutive pipeline events — the
        paper's bottleneck-identification view (e.g. broker faster than the
        consuming processing tasks)."""
        out = {}
        for a, b in zip(EVENTS[:-1], EVENTS[1:]):
            lat = self.latencies(a, b)
            if lat:
                out[f"{a}->{b}"] = {
                    "mean_s": statistics.fmean(lat),
                    "max_s": max(lat), "count": len(lat)}
        return out
