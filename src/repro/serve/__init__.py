from repro.serve.engine import (BatchServer, Request, make_decode_fn,
                                make_prefill_fn, prefill_with_cache)

__all__ = ["BatchServer", "Request", "make_decode_fn", "make_prefill_fn",
           "prefill_with_cache"]
