"""Serving substrate: prefill-with-cache + decode step + batched server.

``prefill_with_cache`` runs the full-sequence forward while *capturing* the
per-layer caches in exactly the layout ``transformer.init_cache`` allocates
(KV heaps / MLA latents / SSM states / sliding-window ring buffers), so the
prefill→decode handoff is bit-consistent with incremental decoding — the
invariant tests/test_serve.py checks token-by-token.

:class:`BatchServer` is the paper's "serve a small model with batched
requests" driver adapted to the pilot world: requests stream in (possibly
through a Broker topic), are packed into fixed decode slots, and each engine
step decodes one token for every active slot (static shapes — one jit).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# prefill with cache capture
# ---------------------------------------------------------------------------


def _ring_scatter(kv, window: int):
    """Last-`window` kv entries, ring-layout (slot = pos % window)."""
    b, s, hkv, hd = kv.shape
    if s <= window:
        pad = jnp.zeros((b, window - s, hkv, hd), kv.dtype)
        return jnp.concatenate([kv, pad], axis=1)
    tail = kv[:, s - window:]                       # positions s-w .. s-1
    slots = (jnp.arange(s - window, s)) % window
    out = jnp.zeros((b, window, hkv, hd), kv.dtype)
    return out.at[:, slots].set(tail)


def _block_prefill(lp, x, cos, sin, cfg: ArchConfig, max_len: int,
                   cache_dtype, *, impl, chunk):
    """block_forward + cache capture. Returns (x, cache_entry)."""
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    b, s, _ = x.shape
    entry: Dict[str, Any] = {}
    if cfg.attn_kind == "gqa":
        a, (k, v) = L.gqa_forward(lp["attn"], h, cos, sin, cfg, impl=impl,
                                  window=cfg.sliding_window, chunk=chunk)
        x = x + a
        size = max_len if cfg.sliding_window is None else min(
            max_len, cfg.sliding_window)
        entry["k"] = _pad_cache(k.astype(cache_dtype), size,
                                cfg.sliding_window)
        entry["v"] = _pad_cache(v.astype(cache_dtype), size,
                                cfg.sliding_window)
    elif cfg.attn_kind == "mla":
        a, (ckv, krope) = L.mla_forward(lp["attn"], h, cos, sin, cfg,
                                        impl=impl, chunk=chunk)
        x = x + a
        entry["ckv"] = _pad_seq(ckv.astype(cache_dtype), max_len)
        entry["krope"] = _pad_seq(krope.astype(cache_dtype), max_len)
    elif cfg.attn_kind == "hybrid":
        ha = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, (k, v) = L.gqa_forward(lp["mixer"]["attn"], ha, cos, sin, cfg,
                                  impl=impl, window=cfg.sliding_window,
                                  chunk=chunk)
        m, (ssm_state, conv_state) = L.ssm_forward(
            lp["mixer"]["ssm"], ha, cfg, return_state=True)
        y = 0.5 * (L.rms_norm(a, lp["mixer"]["attn_norm"], cfg.norm_eps)
                   + L.rms_norm(m, lp["mixer"]["ssm_norm_out"],
                                cfg.norm_eps))
        x = x + y
        size = max_len if cfg.sliding_window is None else min(
            max_len, cfg.sliding_window)
        entry["k"] = _pad_cache(k.astype(cache_dtype), size,
                                cfg.sliding_window)
        entry["v"] = _pad_cache(v.astype(cache_dtype), size,
                                cfg.sliding_window)
        entry["ssm"] = ssm_state
        entry["conv"] = conv_state
    else:                                            # pure SSM
        y, (ssm_state, conv_state) = L.ssm_forward(lp["ssm"], h, cfg,
                                                   return_state=True)
        x = x + y
        entry["ssm"] = ssm_state
        entry["conv"] = conv_state
    if cfg.moe is not None:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = L.moe_forward(lp["moe"], h2, cfg)
        x = x + y
    elif cfg.d_ff:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn_forward(lp["ffn"], h2, cfg.ffn_kind)
    return x, entry


def _pad_seq(x, max_len: int):
    s = x.shape[1]
    if s >= max_len:
        return x[:, :max_len]
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, max_len - s)
    return jnp.pad(x, widths)


def _pad_cache(kv, size: int, window):
    if window is not None and kv.shape[1] > size:
        return _ring_scatter(kv, size)
    return _pad_seq(kv, size)


def prefill_with_cache(params, cfg: ArchConfig, inputs, max_len: int, *,
                       impl="dense", chunk=1024, cache_dtype=jnp.bfloat16,
                       rules=None):
    """Returns (logits (B,S,V...), cache) — cache layout == init_cache."""
    x = T._embed_inputs(params, cfg, inputs)
    seq_len = x.shape[1]
    cos, sin = T._positions_cos_sin(cfg, inputs, seq_len, T._rope_dim(cfg))

    def body(h, lp):
        h, entry = _block_prefill(lp, h, cos, sin, cfg, max_len,
                                  cache_dtype, impl=impl, chunk=chunk)
        return h, entry

    x, cache = lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = T._logits(params, cfg, x, rules)
    return logits, cache


def make_prefill_fn(cfg: ArchConfig, max_len: int, *, impl="dense",
                    chunk=1024, cache_dtype=jnp.bfloat16, rules=None):
    @jax.jit
    def prefill(params, inputs):
        return prefill_with_cache(params, cfg, inputs, max_len, impl=impl,
                                  chunk=chunk, cache_dtype=cache_dtype,
                                  rules=rules)
    return prefill


def make_decode_fn(cfg: ArchConfig, *, rules=None):
    @jax.jit
    def decode(params, cache, inputs):
        return T.decode_step(params, cfg, cache, inputs, rules=rules)
    return decode


# ---------------------------------------------------------------------------
# batched serving
# ---------------------------------------------------------------------------


@dataclass
class Request:
    request_id: str
    prompt: np.ndarray                      # (S,) int32 token ids
    max_new_tokens: int = 32
    temperature: float = 0.0
    result_tokens: List[int] = field(default_factory=list)
    done: threading.Event = field(default_factory=threading.Event)
    t_submit: float = field(default_factory=time.monotonic)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


class BatchServer:
    """Slot-based batched decoder (static shapes, one jitted decode).

    Simplification vs. continuous batching: slots share a step counter, so a
    new request joining mid-flight pads its prompt into the *shared* length
    grid (prefill at slot level). Each slot has an independent KV region
    because caches are per-slot batched arrays.
    """

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self._prefill1 = make_prefill_fn(cfg, max_len)
        self._decode = make_decode_fn(cfg)
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._key = jax.random.key(seed)
        self.metrics: Dict[str, float] = {"decoded_tokens": 0,
                                          "completed": 0}

    def submit(self, req: Request) -> Request:
        self._queue.put(req)
        return req

    def _sample(self, logits, temperature: float):
        if self.cfg.n_codebooks > 1:
            logits = logits[..., 0, :]       # first codebook stream
        logits = logits[..., :self.cfg.vocab_size]   # drop vocab padding
        if temperature <= 0:
            return int(jnp.argmax(logits[0, -1]))
        self._key, k = jax.random.split(self._key)
        return int(jax.random.categorical(k, logits[0, -1] / temperature))

    def run(self, *, max_requests: Optional[int] = None,
            idle_timeout_s: float = 2.0) -> List[Request]:
        """Serve until the queue stays empty for ``idle_timeout_s`` (or
        ``max_requests`` completed). One request per slot wave; waves of up
        to n_slots requests decode in lockstep."""
        completed: List[Request] = []
        pending: List[Request] = []
        while True:
            deadline = time.monotonic() + idle_timeout_s
            while len(pending) < self.n_slots and time.monotonic() < deadline:
                try:
                    pending.append(self._queue.get(timeout=0.05))
                except queue.Empty:
                    if pending:
                        break
            if not pending:
                return completed
            # waves are bucketed by exact prompt length: a shared static
            # prefill shape with left-padding would corrupt RoPE positions
            # and causal masks for the shorter prompts.
            plen = len(pending[0].prompt)
            wave = [r for r in pending if len(r.prompt) == plen][
                :self.n_slots]
            pending = [r for r in pending if r not in wave]
            self._serve_wave(wave)
            completed.extend(wave)
            self.metrics["completed"] += len(wave)
            if max_requests and len(completed) >= max_requests:
                return completed

    def _serve_wave(self, wave: List[Request]) -> None:
        cfg = self.cfg
        s_max = len(wave[0].prompt)                   # bucketed: equal lens
        b = len(wave)
        toks = np.zeros((b, s_max), np.int32)
        for i, r in enumerate(wave):
            toks[i, :] = r.prompt
        if cfg.n_codebooks > 1:
            toks = np.repeat(toks[..., None], cfg.n_codebooks, axis=-1)
        inputs = {"tokens": jnp.asarray(toks)}
        if cfg.input_mode == "embeddings":
            raise NotImplementedError("vlm serving uses embedding frontend")
        logits, cache = self._prefill1(self.params, inputs)
        for i, r in enumerate(wave):
            r.t_first_token = time.monotonic()
        length = s_max
        n_steps = max(r.max_new_tokens for r in wave)
        last = logits[:, -1] if cfg.n_codebooks == 1 else logits[:, -1, 0]
        next_tok = np.asarray(jnp.argmax(last[..., :cfg.vocab_size],
                                         axis=-1), np.int32)
        for i, r in enumerate(wave):
            r.result_tokens.append(int(next_tok[i]))
        for step in range(n_steps - 1):
            t = next_tok[:, None]
            if cfg.n_codebooks > 1:
                t = np.repeat(t[..., None], cfg.n_codebooks, axis=-1)
            dinp = {"tokens": jnp.asarray(t),
                    "length": jnp.asarray(length, jnp.int32)}
            logits, cache = self._decode(self.params, cache, dinp)
            lg = logits[:, 0] if cfg.n_codebooks == 1 else logits[:, 0, 0]
            next_tok = np.asarray(jnp.argmax(lg[..., :cfg.vocab_size],
                                             axis=-1), np.int32)
            self.metrics["decoded_tokens"] += b
            length += 1
            for i, r in enumerate(wave):
                if len(r.result_tokens) < r.max_new_tokens:
                    r.result_tokens.append(int(next_tok[i]))
        now = time.monotonic()
        for r in wave:
            r.t_done = now
            r.done.set()
