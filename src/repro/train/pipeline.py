"""Pipeline parallelism (GPipe) over the `pod` axis.

Beyond-paper parallelism mode for the multi-pod mesh: instead of data-
parallel pods, the two pods form a 2-stage pipeline — layers split
contiguously across stages, microbatches stream through, activations hop
stages over the DCN via ``lax.ppermute``. Inside each stage, the usual
TP(+FSDP) sharding applies on the (data, model) axes (shard_map is manual
over 'pod' only).

Schedule: GPipe with T = M + S − 1 ticks; stage s runs microbatch (t − s)
at tick t; the bubble fraction is (S−1)/T. Activations cross the DCN once
per stage boundary per microbatch — for deep models this is far less DCN
traffic than data-parallel gradient reduction (the §Perf comparison), which
is exactly why PP is the standard cross-DCN axis at 1000+ node scale.

Autodiff: the whole schedule is differentiable — ``ppermute`` transposes to
the reverse permutation, so the backward pass *is* the reverse pipeline.
Every stage holds the embedding/head replicas (they are small next to the
blocks) and masks their use by stage id; the loss is psum'd off the last
stage.

Restrictions (asserted): n_layers % n_stages == 0, global_batch %
microbatches == 0, arch uses the scan-block decoder (all ten do). MoE
aux-losses flow through like the main loss.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models import layers as L
from repro.optim import clip_by_global_norm
from repro.train import step as TS


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int = 2
    microbatches: int = 4
    stage_axis: str = "pod"


def _stage_forward(blocks, x, cos, sin, cfg, rules):
    """Run this stage's contiguous slice of layers (scan)."""
    def body(h, lp):
        h, _ = T.block_forward(lp, h, cos, sin, cfg, impl="dense",
                               chunk=1024, rules=rules)
        return h, None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, blocks)
    return x


def make_pp_loss_fn(cfg: ArchConfig, pc: PipelineConfig,
                    rules: Optional[T.ShardRules]):
    """Returns loss(params, batch) to be used under shard_map manual on the
    stage axis. ``params['blocks']`` leaves carry a leading stage dim of 1
    (this stage's slice); embed/head/ln_f are replicated across stages."""
    S = pc.n_stages
    M = pc.microbatches

    def loss_fn(params, batch):
        sid = lax.axis_index(pc.stage_axis)
        tokens, labels = batch["tokens"], batch["labels"]
        b, seq = tokens.shape[0], tokens.shape[1]
        assert b % M == 0, (b, M)
        mb = b // M
        tok_m = tokens.reshape(M, mb, seq)
        lab_m = labels.reshape(M, mb, seq)
        cos, sin = T._positions_cos_sin(cfg, batch, seq, T._rope_dim(cfg))
        blocks = jax.tree.map(lambda x: x[0], params["blocks"])

        def embed(tok):
            return T._embed_inputs(params, cfg, {"tokens": tok})

        d = cfg.d_model
        buf = jnp.zeros((mb, seq, d),
                        T._embed_inputs(params, cfg,
                                        {"tokens": tok_m[0]}).dtype)
        total_loss = jnp.zeros((), jnp.float32)
        total_tok = jnp.zeros((), jnp.float32)

        def tick(carry, t):
            buf, total_loss, total_tok = carry
            m = t - sid                           # microbatch at this stage
            active = (m >= 0) & (m < M)
            m_c = jnp.clip(m, 0, M - 1)
            # stage 0 sources from the embedding; others from the wire
            x_in = jnp.where(sid == 0, embed(tok_m[m_c]), buf)
            y = _stage_forward(blocks, x_in, cos, sin, cfg, rules)
            # last stage computes the loss for its finished microbatch
            h = L.rms_norm(y, params["ln_f"], cfg.norm_eps)
            logits = T._logits(params, cfg, h, rules)
            vp = cfg.padded_vocab_size
            lg = logits.astype(jnp.float32)
            if vp != cfg.vocab_size:
                lg = jnp.where(jnp.arange(vp) >= cfg.vocab_size, -1e30, lg)
            lse = jax.nn.logsumexp(lg, axis=-1)
            oh = jax.nn.one_hot(lab_m[m_c], vp, dtype=lg.dtype)
            gold = jnp.einsum("...v,...v->...", lg, oh)
            ce = (lse - gold).sum()
            is_last = sid == S - 1
            total_loss = total_loss + jnp.where(active & is_last, ce, 0.0)
            total_tok = total_tok + jnp.where(active & is_last,
                                              jnp.float32(mb * seq), 0.0)
            # ship activations to the next stage (ring; last->0 discarded)
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = lax.ppermute(y, pc.stage_axis, perm)
            return (buf, total_loss, total_tok), None

        (buf, total_loss, total_tok), _ = lax.scan(
            tick, (buf, total_loss, total_tok), jnp.arange(M + S - 1))
        # average over all tokens; psum so every stage returns the same
        loss = (lax.psum(total_loss, pc.stage_axis)
                / jnp.maximum(lax.psum(total_tok, pc.stage_axis), 1.0))
        return loss

    return loss_fn


def make_pp_train_step(cfg: ArchConfig, tc: TS.TrainConfig,
                       pc: PipelineConfig, rules, mesh):
    """Full PP train step: shard_map(manual over stage axis) around
    loss→grad→opt. Params: blocks sharded on the stage axis (leading layer
    dim), embed/head/ln_f replicated across stages (their grads psum'd)."""
    assert cfg.n_layers % pc.n_stages == 0
    opt = TS._opt(cfg, tc)
    inner_rules = dataclasses.replace(
        rules, batch=tuple(a for a in rules.batch if a != pc.stage_axis))
    loss_fn = make_pp_loss_fn(cfg, pc, inner_rules)

    def body(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # replicated leaves (embed/head/ln_f) accumulate grads on every
        # stage: psum them; block grads are stage-local.
        grads = {k: (v if k == "blocks"
                     else jax.tree.map(
                         lambda g: lax.psum(g, pc.stage_axis), v))
                 for k, v in grads.items()}
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        updates, new_opt = opt.update(grads, state["opt"], params,
                                      state["step"])
        new_params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)
        new_state = {"opt": new_opt, "step": state["step"] + 1}
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    def spec_of(tree, stage_spec):
        return jax.tree.map(lambda _: stage_spec, tree)

    def make_specs(params_like):
        pspec = {k: (spec_of(v, P(pc.stage_axis))
                     if k == "blocks" else spec_of(v, P()))
                 for k, v in params_like.items()}
        return pspec

    def step_fn(params, state, batch):
        pspec = make_specs(params)
        # opt state mirrors params: anything under 'blocks' stage-sharded
        sspec = {"opt": _opt_specs(state["opt"], pc), "step": P()}
        bspec = {k: P() for k in batch}
        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(pspec, sspec, bspec),
            out_specs=(pspec, sspec, {"loss": P(), "grad_norm": P()}),
            axis_names={pc.stage_axis}, check_vma=False)
        return fn(params, state, batch)

    return step_fn


def _opt_specs(opt_state, pc: PipelineConfig):
    """Optimizer state mirrors param structure: anything under a 'blocks'
    key is stage-sharded, the rest replicated."""
    def rec(tree, under_blocks=False):
        if isinstance(tree, dict):
            return {k: rec(v, under_blocks or k == "blocks")
                    for k, v in tree.items()}
        return P(pc.stage_axis) if under_blocks else P()
    return rec(opt_state)


def init_pp_state(key, cfg: ArchConfig, tc: TS.TrainConfig,
                  pc: PipelineConfig, dtype=jnp.float32):
    """Host-side init: standard params with blocks reshaped to a leading
    (n_stages, L/S) stage dim so the stage axis shards cleanly."""
    params = T.init_params(key, cfg, dtype)
    S = pc.n_stages
    params["blocks"] = jax.tree.map(
        lambda x: x.reshape(S, cfg.n_layers // S, *x.shape[1:]),
        params["blocks"])
    opt = TS._opt(cfg, tc)
    state = {"opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    return params, state
