"""Train-step factory: loss → grad → (accumulate) → clip → optimizer.

Supports:
* gradient accumulation over microbatches (``lax.scan`` — XLA overlaps the
  next microbatch's compute with the previous collective),
* remat (inherited from the model's scan-over-layers checkpoint policy),
* optional int8 cross-pod gradient compression with error feedback
  (``grad_compression='int8_pod'``; runs the grad path under shard_map
  manual on the 'pod' axis, auto elsewhere),
* AdamW / Adafactor per arch config.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer
from repro.optim.compression import tree_compressed_psum


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    microbatches: int = 1
    accum_dtype: str = "float32"       # bfloat16 for the >=100B archs
    attn_impl: str = "dense"           # dense | chunked | pallas
    attn_chunk: int = 1024
    grad_compression: Optional[str] = None   # None | 'int8_pod'
    moment_dtype: str = "float32"


def _opt(cfg: ArchConfig, tc: TrainConfig):
    lr_fn = cosine_schedule(tc.lr, tc.warmup, tc.total_steps)
    if cfg.optimizer == "adafactor":
        return make_optimizer("adafactor", lr_fn)
    return make_optimizer("adamw", lr_fn,
                          moment_dtype=jnp.dtype(tc.moment_dtype))


def init_train_state(key, cfg: ArchConfig, tc: TrainConfig,
                     dtype=jnp.float32):
    params = T.init_params(key, cfg, dtype)
    opt = _opt(cfg, tc)
    state = {"opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    if tc.grad_compression == "int8_pod":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    return params, state


def train_state_shapes(cfg: ArchConfig, tc: TrainConfig,
                       dtype=jnp.bfloat16):
    """ShapeDtypeStructs for (params, opt_state) — dry-run, no allocation."""
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, tc, dtype), jax.random.key(0))


def _factored_spec(spec, ndim, drop_axis):
    parts = list(spec) + [None] * (ndim - len(spec))
    del parts[drop_axis]
    return P(*parts)


def train_state_pspecs(cfg: ArchConfig, tc: TrainConfig, rules: T.ShardRules,
                       params_tree):
    """PartitionSpec tree matching init_train_state structure exactly."""
    pspecs = T.param_pspecs(cfg, rules)
    if cfg.optimizer == "adafactor":
        def per_leaf(p, spec):
            if p.ndim >= 2:
                return {"vr": _factored_spec(spec, p.ndim, p.ndim - 1),
                        "vc": _factored_spec(spec, p.ndim, p.ndim - 2)}
            return {"v": spec}
        opt_spec = {"v": jax.tree.map(per_leaf, params_tree, pspecs)}
    else:
        opt_spec = {"mu": pspecs, "nu": pspecs}
    state_spec = {"opt": opt_spec, "step": P()}
    if tc.grad_compression == "int8_pod":
        state_spec["ef"] = pspecs
    return pspecs, state_spec


def batch_pspec(cfg: ArchConfig, rules: T.ShardRules):
    b = rules.batch
    spec = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.n_codebooks > 1:
        spec = {"tokens": P(b, None, None), "labels": P(b, None, None)}
    if cfg.input_mode == "embeddings":
        spec = {"embeds": P(b, None, None), "positions": P(None, b, None),
                "labels": P(b, None)}
    return spec


def make_train_step(cfg: ArchConfig, tc: TrainConfig,
                    rules: Optional[T.ShardRules] = None):
    opt = _opt(cfg, tc)
    accum_dtype = jnp.dtype(tc.accum_dtype)

    def loss(params, batch):
        return T.loss_fn(params, cfg, batch, impl=tc.attn_impl,
                         chunk=tc.attn_chunk, rules=rules)

    grad_fn = jax.grad(loss, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatches == 1:
            return grad_fn(params, batch)
        m = tc.microbatches

        def resh(x):
            b = x.shape[0]
            assert b % m == 0, (b, m)
            return x.reshape(m, b // m, *x.shape[1:])

        # positions (3,B,S) has batch second — handle leading-batch only
        mb = {}
        for k, v in batch.items():
            if k == "positions":
                mb[k] = v.reshape(v.shape[0], m, v.shape[1] // m,
                                  *v.shape[2:]).swapaxes(0, 1)
            else:
                mb[k] = resh(v)

        def body(acc, micro):
            g, metrics = grad_fn(params, micro)
            acc_g, acc_m = acc
            acc_g = jax.tree.map(
                lambda a, x: a + x.astype(accum_dtype), acc_g, g)
            acc_m = jax.tree.map(lambda a, x: a + x / m, acc_m, metrics)
            return (acc_g, acc_m), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        _, m0 = jax.eval_shape(grad_fn, params, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype)
            if False else x[0], mb))
        m0 = jax.tree.map(lambda s: jnp.zeros((), jnp.float32), m0)
        (g, metrics), _ = lax.scan(body, (g0, m0), mb)
        g = jax.tree.map(lambda x, p: (x / m).astype(p.dtype), g, params)
        return g, metrics

    def train_step(params, state, batch):
        grads, metrics = compute_grads(params, batch)
        new_state = dict(state)
        if tc.grad_compression == "int8_pod":
            grads, new_ef = tree_compressed_psum(grads, "pod", state["ef"])
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads,
                                 params)
            new_state["ef"] = new_ef
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        updates, new_opt = opt.update(grads, state["opt"], params,
                                      state["step"])
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          + u.astype(jnp.float32)).astype(p.dtype),
            params, updates)
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_params, new_state, metrics

    return train_step


def make_compressed_train_step(cfg: ArchConfig, tc: TrainConfig,
                               rules: T.ShardRules, mesh):
    """int8-compressed cross-pod DP: the whole step runs under shard_map
    manual on the 'pod' axis (auto on data/model), so the pod-axis gradient
    reduction is our explicit int8 psum instead of GSPMD's bf16 all-reduce.

    Params/optimizer state are replicated across pods (P() on 'pod'); the
    batch is split on 'pod'. Inside the body, grads are pod-local partial
    sums; ``tree_compressed_psum`` produces the exact int8-quantized average
    with error feedback carried in ``state['ef']``.
    """
    assert tc.grad_compression == "int8_pod"
    opt = _opt(cfg, tc)
    # inside the manual 'pod' region only the auto axes may appear in
    # sharding constraints — drop 'pod' from the batch rule
    inner_rules = dataclasses.replace(
        rules, batch=tuple(a for a in rules.batch if a != "pod"))

    def body(params, state, batch):
        grads, metrics = jax.grad(
            lambda p, b: T.loss_fn(p, cfg, b, impl=tc.attn_impl,
                                   chunk=tc.attn_chunk, rules=inner_rules),
            has_aux=True)(params, batch)
        grads, new_ef = tree_compressed_psum(grads, "pod", state["ef"])
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        updates, new_opt = opt.update(grads, state["opt"], params,
                                      state["step"])
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          + u.astype(jnp.float32)).astype(p.dtype),
            params, updates)
        new_state = dict(state)
        new_state["ef"] = new_ef
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), metrics)
        return new_params, new_state, metrics

    def specs_of(tree_):
        return jax.tree.map(lambda _: P(), tree_)

    def step_fn(params, state, batch):
        # batch split on 'pod'; positions (3,B,S) carry batch on dim 1
        bspec = {}
        for k, v in batch.items():
            if k == "positions":
                bspec[k] = P(None, "pod")
            else:
                bspec[k] = P(*("pod",) + (None,) * (v.ndim - 1))
        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(specs_of(params), specs_of(state), bspec),
            out_specs=(specs_of(params), specs_of(state),
                       {"ce": P(), "loss": P(), "grad_norm": P(),
                        **({"lb_loss": P(), "z_loss": P(),
                            "dropped_frac": P()} if cfg.moe is not None
                           else {})}),
            axis_names={"pod"}, check_vma=False)
        return fn(params, state, batch)

    return step_fn
