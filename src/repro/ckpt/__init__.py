from repro.ckpt.checkpoint import (CheckpointManager, restore, save,
                                   latest_step)

__all__ = ["CheckpointManager", "restore", "save", "latest_step"]
