"""Sharded checkpoints with atomic commit + reshard-on-restore.

Fault-tolerance substrate for the multi-pod story:

* ``save(step, tree, dir)`` — each pytree leaf is written as one ``.npy``
  inside a temp directory, then the directory is atomically renamed to
  ``step_<n>`` (a torn write can never be mistaken for a checkpoint).
  A ``manifest.json`` records the tree structure, shapes and dtypes.
* ``restore(dir, step, mesh=None, pspecs=None)`` — loads leaves and, when a
  mesh + PartitionSpec tree is given, ``device_put``s each leaf with its
  NamedSharding. Because the on-disk format is full (unsharded) arrays, a
  checkpoint written on a 512-chip mesh restores cleanly onto 256 chips
  (or 1 CPU) — reshard-on-restore, the recovery path core/elastic.py uses
  after losing a pod.
* ``CheckpointManager`` — keep-last-N rotation + async save (the train
  driver checkpoints without stalling the step loop).

On a real multi-host deployment each host writes only the shards it owns;
here (single process) we write full arrays — the commit protocol, manifest
and reshard logic are identical.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any) -> str:
    """Atomic checkpoint write. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=directory)
    try:
        leaves, _ = _flatten_with_paths(tree)
        manifest = {"step": step, "leaves": []}
        for i, (key, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"leaf_{i}.npy"
            np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):          # overwrite = replace atomically
            shutil.rmtree(final)
        os.rename(tmp, final)              # the atomic commit
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None, *,
            like: Any, mesh=None, pspecs: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With ``mesh`` + ``pspecs``, leaves are placed with
    NamedShardings — resharding onto whatever mesh is alive now."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_like, treedef = _flatten_with_paths(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    restored = []
    spec_leaves = None
    if pspecs is not None:
        spec_leaves = treedef.flatten_up_to(pspecs)
    for i, (key, leaf_like) in enumerate(leaves_like):
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = np.load(os.path.join(path, entry["file"]),
                      allow_pickle=False)
        want_dtype = getattr(leaf_like, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if mesh is not None and spec_leaves is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec_leaves[i])
            arr = jax.device_put(arr, sharding)
        else:
            arr = jax.device_put(arr)
        restored.append(arr)
    return jax.tree.unflatten(treedef, restored)


class CheckpointManager:
    """keep-last-N rotation + optional async writes."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now
        if self.async_save:
            self.wait()
            t = threading.Thread(target=self._save_and_gc,
                                 args=(step, host_tree), daemon=True)
            t.start()
            self._pending = t
        else:
            self._save_and_gc(step, host_tree)

    def _save_and_gc(self, step: int, tree: Any) -> None:
        save(self.directory, step, tree)
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := _STEP_RE.match(d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, like: Any, mesh=None, pspecs=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore(self.directory, step, like=like, mesh=mesh,
                             pspecs=pspecs)
